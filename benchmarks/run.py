"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Sections:
1. matchbench        — progress-engine post+match throughput (keyed vs
                       legacy scan), emits BENCH_progress.json
2. pingpong          — paper Fig. 1 (lanes sweep × 3 designs)
3. lcx_collectives   — LCX ring/pairwise vs native XLA collectives
4. moe_dispatch      — EP a2a dispatch throughput (LCX a2a backends)
5. kernels_bench     — Pallas kernels vs oracles
6. chaosbench        — seeded fault-injection sweep (convergence),
                       emits BENCH_chaos.json at repo root
7. failoverbench     — kill-every-N chaos soak (recovery latency,
                       goodput), emits BENCH_failover.json at repo root
8. isolationbench    — per-device throughput isolation (resource
                       hierarchy), emits BENCH_isolation.json
CSV outputs land in results/.
"""
import argparse
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.dirname(__file__))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true",
                   help="trim the lane sweep for CI")
    args = p.parse_args()

    os.makedirs("results", exist_ok=True)

    print("=" * 72)
    print("0. matching/progress fast path (keyed engine vs legacy scan)")
    print("=" * 72)
    import matchbench
    mb_args = ["--out", "results/BENCH_progress.json"]
    if args.fast:
        mb_args.append("--smoke")
    matchbench.main(mb_args)

    print("=" * 72)
    print("1. ping-pong (paper Fig. 1: message rate vs concurrent lanes)")
    print("=" * 72)
    import pingpong
    if args.fast:
        pingpong.LANES = (1, 8, 64)
        pingpong.REPEAT = 10
    pingpong.main(out_csv="results/pingpong.csv")

    print("=" * 72)
    print("2. LCX collectives vs native")
    print("=" * 72)
    import lcx_collectives
    lcx_collectives.main(out_csv="results/lcx_collectives.csv")

    print("=" * 72)
    print("3. MoE EP dispatch (LCX a2a)")
    print("=" * 72)
    import moe_dispatch
    moe_dispatch.main(out_csv="results/moe_dispatch.csv")

    print("=" * 72)
    print("4. Pallas kernels vs oracles")
    print("=" * 72)
    import kernels_bench
    kernels_bench.main(out_csv="results/kernels.csv")

    print("=" * 72)
    print("5. chaos sweep (seeded fault injection must converge)")
    print("=" * 72)
    import chaosbench
    cb_args = ["--out", os.path.join(ROOT, "BENCH_chaos.json")]
    if args.fast:
        cb_args.append("--smoke")
    chaosbench.main(cb_args)

    print("=" * 72)
    print("6. failover soak (kill-every-N: recovery latency + goodput)")
    print("=" * 72)
    import failoverbench
    fb_args = ["--out", os.path.join(ROOT, "BENCH_failover.json")]
    if args.fast:
        fb_args.append("--smoke")
    failoverbench.main(fb_args)

    print("=" * 72)
    print("7. device isolation (busy neighbor must not steal throughput)")
    print("=" * 72)
    import isolationbench
    ib_args = ["--out", "results/BENCH_isolation.json"]
    if args.fast:
        ib_args.append("--smoke")
    isolationbench.main(ib_args)

    print("benchmarks complete; CSVs in results/")


if __name__ == "__main__":
    main()
