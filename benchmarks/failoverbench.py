"""Failover benchmark: recovery latency and goodput under a
kill-every-N-steps chaos soak.

Drives rounds of completion-tracked ``put``s over a lossy transport
(seeded drop) with a :class:`HeartbeatMonitor` attached.  Every
``--kill-every`` rounds the current primary device is frozen *mid-round*
(in-flight transfers stall); the monitor declares it dead from missing
beats and ``runtime.failover`` migrates the stalled ledger, retry queue,
and un-matched ops onto the least-loaded survivor.  The soak asserts
exactly-once delivery for every round — raced transfers are neither lost
nor double-delivered (per-op sequence numbers + the dedup window).

Reported per kill: detection latency (ticks from freeze to the heartbeat
declaration), drain latency (ticks from freeze until every in-flight
transfer completed on the survivor), and migrated-op counts.  Aggregate:
goodput (deliveries/s and deliveries/progress-call) and the runtime's
``failover_stats``.  ``--kills N`` sets the kill count (the soak
provisions N standby devices); ``--smoke`` shrinks the soak for CI;
``--out FILE`` writes the JSON rows (wired to ``BENCH_failover.json``
by ``benchmarks/run.py``).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax.numpy as jnp

import repro.core as lcx
from repro.runtime.fault import HeartbeatMonitor


def run_soak(kills: int, kill_every: int, n_tasks: int, seed: int,
             drop: float = 0.1, max_retries: int = 64) -> Dict[str, object]:
    """Kill-every-N-rounds soak.  Returns aggregate + per-kill rows."""
    lcx.init()
    rt = lcx.runtime()
    lcx.install_transport(lcx.FaultyTransport(seed=seed, drop=drop))
    hb = HeartbeatMonitor(threshold=2.0, patience=2, grace=3,
                          on_dead="failover").attach(rt)
    # one primary + one standby per kill (failover targets the
    # least-loaded survivor, so each kill consumes one standby)
    standbys = [rt.device() for _ in range(kills + 1)]
    primary = standbys.pop(0)
    cq = lcx.CompletionQueue()

    # beat history so the monitor has an EMA before the first kill
    for _ in range(4):
        lcx.progress()

    rounds = kills * kill_every
    per_kill: List[Dict[str, float]] = []
    delivered_total = 0
    t0 = time.perf_counter()
    for r in range(rounds):
        kill_round = (r + 1) % kill_every == 0 and len(per_kill) < kills
        for i in range(n_tasks):
            lcx.put_x(jnp.float32(r * n_tasks + i)).remote_comp(cq) \
                .device(primary).tag(i).max_retries(max_retries)()
        freeze_tick = None
        detect_tick = None
        if kill_round:
            # freeze before the first progress call of the round: every
            # transfer of this round is in flight when the device dies
            freeze_tick = rt.tick
            primary.freeze()
            n_events_before = len(hb.events)
        for _ in range(600):
            lcx.progress()
            if kill_round and detect_tick is None \
                    and len(hb.events) > n_events_before:
                detect_tick = rt.tick
            if len(cq) >= n_tasks and not rt.has_inflight():
                break
        evs = cq.pop_all()
        payloads = sorted(float(ev.payload) for ev in evs)
        expect = [float(r * n_tasks + i) for i in range(n_tasks)]
        assert payloads == expect, \
            f"round {r}: exactly-once violated ({len(evs)} events)"
        delivered_total += len(evs)
        if kill_round:
            ev = hb.events[-1]
            assert detect_tick is not None, "kill never detected"
            per_kill.append({
                "round": r,
                "detect_ticks": detect_tick - freeze_tick,
                "drain_ticks": rt.tick - freeze_tick,
                "migrated_ops": (ev["report"].n_ledger
                                 + ev["report"].n_retry
                                 + ev["report"].n_engine_ops),
            })
            primary = ev["target"]
    dt = time.perf_counter() - t0

    stats = rt.failover_stats
    assert stats["failovers"] == kills, stats
    return {
        "kills": kills, "rounds": rounds, "tasks_per_round": n_tasks,
        "drop": drop, "seconds": dt,
        "delivered": delivered_total,
        "goodput_per_s": delivered_total / dt,
        "deliveries_per_tick": delivered_total / max(rt.tick, 1),
        "ticks": rt.tick,
        "mean_detect_ticks": (sum(k["detect_ticks"] for k in per_kill)
                              / max(len(per_kill), 1)),
        "mean_drain_ticks": (sum(k["drain_ticks"] for k in per_kill)
                             / max(len(per_kill), 1)),
        "dedup_suppressed": stats["dedup_suppressed"],
        "migrated_ops": stats["migrated_ops"],
        "per_kill": per_kill,
    }


def main(argv: List[str] = None) -> Dict[str, object]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kills", type=int, default=3,
                    help="devices to kill over the soak")
    ap.add_argument("--kill-every", type=int, default=2,
                    help="rounds between kills")
    ap.add_argument("--smoke", action="store_true",
                    help="small soak for CI")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--n", type=int, default=None,
                    help="override transfers per round")
    ap.add_argument("--drop", type=float, default=0.1,
                    help="seeded transport drop rate")
    ap.add_argument("--out", type=str, default=None,
                    help="write the JSON report here")
    args = ap.parse_args(argv)

    n = args.n if args.n is not None else (8 if args.smoke else 32)
    row = run_soak(args.kills, args.kill_every, n, args.seed,
                   drop=args.drop)

    print(f"{'kill':>5s} {'detect':>7s} {'drain':>6s} {'migrated':>9s}")
    for k in row["per_kill"]:
        print(f"{k['round']:5d} {k['detect_ticks']:7d} "
              f"{k['drain_ticks']:6d} {k['migrated_ops']:9d}")
    print(f"{row['kills']} kills over {row['rounds']} rounds: "
          f"recovery latency {row['mean_detect_ticks']:.1f} ticks detect "
          f"/ {row['mean_drain_ticks']:.1f} ticks drain; "
          f"goodput {row['goodput_per_s']:.0f} deliveries/s "
          f"({row['deliveries_per_tick']:.2f}/tick), "
          f"{row['dedup_suppressed']} duplicates suppressed")
    print("all rounds delivered exactly once")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(row, f, indent=2)
        print(f"wrote {args.out}")
    print("FAILOVERBENCH_JSON=" + json.dumps(
        {k: v for k, v in row.items() if k != "per_kill"}))
    return row


if __name__ == "__main__":
    main()
