"""MoE expert-parallel dispatch microbenchmark (the paper's fine-grained
asynchronous a2a pattern, LCX-routed).

Sweeps token counts through the sort-based capacity dispatch + EP
all-to-all (2 fake-device subprocess like the ping-pong) and reports
tokens/s plus drop rate at the configured capacity factor.  Single-pod
the dominant MoE cost is exactly this path (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

TOKENS = (256, 1024, 4096)
N_RANKS = 2


def _run_inproc(n_tokens: int, a2a_backend: str) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ModelConfig
    from repro.models.moe import moe_init, moe_apply
    from repro.parallel.sharding import use_mesh, param_shardings
    from repro.compat import make_mesh

    mesh = make_mesh((1, N_RANKS), ("data", "model"))
    cfg = ModelConfig(name="bench", family="moe", n_layers=1, d_model=128,
                      n_heads=2, n_kv_heads=2, d_ff=256, vocab=64,
                      n_experts=8, n_experts_per_tok=2, moe_d_ff=256,
                      moe_backend="lcx", capacity_factor=1.25,
                      dtype=jnp.float32, param_dtype=jnp.float32)
    cfg.moe_a2a = a2a_backend      # LCX a2a lowering knob
    params, dims = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, n_tokens, 128), jnp.float32)
    with use_mesh(mesh):
        psh = param_shardings(dims, params, mesh)
        params_s = jax.device_put(params, psh)
        x_s = jax.device_put(x, NamedSharding(mesh, P(None, "model",
                                                      None)))
        fn = jax.jit(lambda p, t: moe_apply(cfg, p, t)[0])
        out = fn(params_s, x_s)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(10):
            out = fn(params_s, x_s)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 10
    return {"tokens": n_tokens, "a2a": a2a_backend,
            "us_per_call": dt * 1e6, "tokens_per_s": n_tokens / dt}


def _child() -> None:
    rows = []
    for t in TOKENS:
        for backend in ("native", "pairwise"):
            rows.append(_run_inproc(t, backend))
    print("MOEDISPATCH_JSON=" + json.dumps(rows))


def main(out_csv: str = None) -> List[Dict[str, float]]:
    import jax
    if len(jax.devices()) >= N_RANKS:
        rows = []
        for t in TOKENS:
            for backend in ("native", "pairwise"):
                rows.append(_run_inproc(t, backend))
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count=2")
        env["MOEDISPATCH_CHILD"] = "1"
        out = subprocess.run([sys.executable, os.path.abspath(__file__)],
                             capture_output=True, text=True, env=env,
                             timeout=1200)
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-2000:])
        line = [l for l in out.stdout.splitlines()
                if l.startswith("MOEDISPATCH_JSON=")][0]
        rows = json.loads(line[len("MOEDISPATCH_JSON="):])
    print(f"{'tokens':>7s} {'a2a':9s} {'us/call':>10s} {'Mtok/s':>8s}")
    for r in rows:
        print(f"{r['tokens']:7d} {r['a2a']:9s} {r['us_per_call']:10.1f} "
              f"{r['tokens_per_s']/1e6:8.3f}")
    if out_csv:
        import csv
        with open(out_csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return rows


if __name__ == "__main__":
    if os.environ.get("MOEDISPATCH_CHILD"):
        _child()
    else:
        main()
