"""LCX p2p-built collectives vs native XLA — structural cost table.

For each collective (all-gather / reduce-scatter / all-reduce /
all-to-all) and backend (lcx ring|pairwise vs native), report wall time
(vmap-emulated ranks on CPU) and the LCX device/pool statistics (number
of p2p transfers, bytes moved) — the schedule the ring algorithms post.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

import repro.core as lcx

N = 8
SIZE = 1 << 14       # elements per rank
REPEAT = 20


def bench(op: str, backend: str) -> Dict[str, float]:
    stats = {}

    def body(x):
        lcx.init()
        dev = lcx.Device(axis="x")
        if op == "all_gather":
            out = lcx.all_gather(x, device=dev, backend=backend)
        elif op == "reduce_scatter":
            out = lcx.reduce_scatter(x, device=dev, backend=backend)
        elif op == "all_reduce":
            out = lcx.all_reduce(x, device=dev, backend=backend)
        else:
            out = lcx.all_to_all(x, device=dev, backend=backend)
        stats.update(dev.stats)
        return out

    xs = jnp.arange(float(N * SIZE)).reshape(N, SIZE)
    fn = jax.jit(jax.vmap(body, axis_name="x"))
    out = fn(xs)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(REPEAT):
        out = fn(xs)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / REPEAT
    return {"op": op, "backend": backend, "us_per_call": dt * 1e6,
            "p2p_transfers": stats.get("transfers", 0),
            "bytes_per_rank": stats.get("bytes_moved", 0)}


def main(out_csv: str = None) -> List[Dict[str, float]]:
    rows = []
    print(f"{'op':16s} {'backend':9s} {'us/call':>10s} "
          f"{'p2p':>5s} {'KiB/rank':>9s}")
    for op in ("all_gather", "reduce_scatter", "all_reduce",
               "all_to_all"):
        backends = ("pairwise", "native") if op == "all_to_all" \
            else ("ring", "native")
        for backend in backends:
            r = bench(op, backend)
            rows.append(r)
            print(f"{r['op']:16s} {r['backend']:9s} "
                  f"{r['us_per_call']:10.1f} {r['p2p_transfers']:5d} "
                  f"{r['bytes_per_rank']/1024:9.1f}")
    if out_csv:
        import csv
        with open(out_csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
