"""AMT task-executor throughput / overhead benchmark.

Measures the scheduling cost the executor adds on top of raw Python
calls, across graph shapes that stress different parts of the worker
loop:

- ``chain``   — N serially dependent tasks (dependency bookkeeping);
- ``fanout``  — 1 source, N independent leaves (ready-heap churn);
- ``diamond`` — D layers of W-wide fan-out/fan-in (mixed);
- ``comm``    — N communication tasks, each posting a loopback LCX put
  and suspending until the completion queue retires it (the
  progress-interleaved path the GPipe schedule exercises).

Reported per shape: wall time, tasks/s, and per-task overhead versus a
bare-Python-loop baseline running the same bodies.  ``--smoke`` runs a
tiny configuration (CI sanity); ``--csv`` dumps rows.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax.numpy as jnp

import repro.core as lcx
from repro.amt import Executor


def _noop_body(ctx):
    return 0


def bench_chain(n: int) -> Dict[str, float]:
    lcx.init()
    ex = Executor(name="chain")
    prev = None
    t0 = time.perf_counter()
    for i in range(n):
        prev = ex.spawn(_noop_body, deps=(prev,) if prev else ())
    ex.run()
    dt = time.perf_counter() - t0
    return {"shape": "chain", "tasks": n, "seconds": dt}


def bench_fanout(n: int) -> Dict[str, float]:
    lcx.init()
    ex = Executor(name="fanout")
    t0 = time.perf_counter()
    src = ex.spawn(_noop_body)
    for i in range(n - 1):
        ex.spawn(_noop_body, deps=(src,), priority=i % 7)
    ex.run()
    dt = time.perf_counter() - t0
    return {"shape": "fanout", "tasks": n, "seconds": dt}


def bench_diamond(layers: int, width: int) -> Dict[str, float]:
    lcx.init()
    ex = Executor(name="diamond")
    t0 = time.perf_counter()
    top = ex.spawn(_noop_body)
    for _ in range(layers):
        mids = [ex.spawn(_noop_body, deps=(top,)) for _ in range(width)]
        top = ex.spawn(_noop_body, deps=tuple(mids))
    ex.run()
    dt = time.perf_counter() - t0
    n = 1 + layers * (width + 1)
    return {"shape": "diamond", "tasks": n, "seconds": dt}


def bench_comm(n: int, progress_every: int = 8) -> Dict[str, float]:
    """Loopback puts retired through the executor's completion queue."""
    lcx.init()
    ex = Executor(progress_every=progress_every, name="comm")
    x = jnp.float32(1.0)

    def maker(i):
        def fn(ctx):
            ctx.put(x, None, tag=i % (1 << 15))
            return ctx.suspend(lambda ev: 0)
        return fn

    t0 = time.perf_counter()
    for i in range(n):
        ex.spawn(maker(i))
    stats = ex.run()
    dt = time.perf_counter() - t0
    return {"shape": "comm", "tasks": n, "seconds": dt,
            "progress_calls": stats["progress_calls"],
            "events_retired": stats["events_retired"]}


def bench_baseline(n: int) -> Dict[str, float]:
    """The same no-op bodies as a bare Python loop (no scheduler)."""
    t0 = time.perf_counter()
    acc = 0
    for _ in range(n):
        acc += _noop_body(None)
    dt = time.perf_counter() - t0
    return {"shape": "baseline", "tasks": n, "seconds": dt}


def main() -> List[Dict[str, float]]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI sanity")
    ap.add_argument("--n", type=int, default=None,
                    help="override task count")
    ap.add_argument("--csv", type=str, default=None)
    args = ap.parse_args()

    n = args.n if args.n is not None else (200 if args.smoke else 20000)
    if n < 1:
        ap.error("--n must be >= 1")
    layers, width = (4, 8) if args.smoke else (40, 32)

    rows = [
        bench_baseline(n),
        bench_chain(n),
        bench_fanout(n),
        bench_diamond(layers, width),
        bench_comm(200 if args.smoke else 2000),
    ]
    base_per_task = rows[0]["seconds"] / rows[0]["tasks"]
    print(f"{'shape':10s} {'tasks':>8s} {'ms total':>10s} "
          f"{'tasks/s':>12s} {'us/task':>9s} {'overhead us':>12s}")
    for r in rows:
        per = r["seconds"] / r["tasks"]
        r["tasks_per_s"] = r["tasks"] / max(r["seconds"], 1e-12)
        r["overhead_us"] = (per - base_per_task) * 1e6
        print(f"{r['shape']:10s} {r['tasks']:8d} "
              f"{r['seconds'] * 1e3:10.2f} {r['tasks_per_s']:12.0f} "
              f"{per * 1e6:9.2f} {r['overhead_us']:12.2f}")

    if args.csv:
        import csv
        keys = sorted({k for r in rows for k in r})
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(rows)
    print("AMT_TASKBENCH_JSON=" + json.dumps(rows))
    return rows


if __name__ == "__main__":
    main()
