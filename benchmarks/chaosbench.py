"""Chaos benchmark: seeded fault-injection sweep over the LCX stack.

Runs an AMT executor workload (tasks posting loopback puts with retry
budgets, suspended on the completion queue) under a grid of
`FaultyTransport` policies — drop / delay / duplicate at 1–10% rates —
and asserts that every configuration *converges*: all payloads
delivered correctly, no hang, no executor teardown.  A final
unrecoverable scenario (100% drop, bounded retries + deadline) must
surface `fatal`/`timeout` completions within the op's deadline instead
of hanging.

Reported per cell: wall time, progress calls, transport fault counts,
and retries spent.  ``--smoke`` shrinks the grid for CI (wired into
the chaos job with a hard timeout so a hang fails fast); ``--seed``
re-rolls the fault schedule deterministically.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax.numpy as jnp

import repro.core as lcx
from repro.amt import Executor


def run_cell(kind: str, rate: float, n_tasks: int, seed: int,
             max_retries: int = 12) -> Dict[str, float]:
    """One sweep cell: n_tasks executor tasks, each putting its index
    over a lossy loopback transport and suspending until delivery."""
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=seed, **{kind: rate}))
    ex = Executor(name=f"chaos-{kind}", fail_fast=False)
    got: List[float] = []

    def worker(ctx, i):
        ctx.put(jnp.float32(i), None, tag=i, max_retries=max_retries)
        return ctx.suspend(lambda ev: got.append(float(ev.payload)))

    t0 = time.perf_counter()
    for i in range(n_tasks):
        ex.spawn(lambda ctx, _i=i: worker(ctx, _i), name=f"w{i}")
    stats = ex.run()
    dt = time.perf_counter() - t0

    tstats = lcx.runtime().transport.stats
    delivered = sorted(got)
    # duplicates deliver the same payload twice; convergence means every
    # expected payload arrived at least once and none were corrupted
    expect = [float(i) for i in range(n_tasks)]
    assert sorted(set(delivered)) == expect, \
        f"{kind}@{rate}: delivered {delivered[:8]}... != expected"
    assert tstats["fatal"] == 0, f"{kind}@{rate}: unexpected fatal"
    return {"kind": kind, "rate": rate, "tasks": n_tasks,
            "seconds": dt, "progress_calls": stats["progress_calls"],
            "faults": tstats[_STAT_KEY[kind]], "retries": tstats["retries"],
            "extra_deliveries": len(delivered) - n_tasks}


_STAT_KEY = {"drop": "drops", "delay": "delays", "duplicate": "duplicates"}


def run_unrecoverable(seed: int) -> Dict[str, float]:
    """100% drop with a bounded budget and deadline: must surface
    fatal/timeout completions promptly — the no-infinite-hang check."""
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=seed, drop=1.0))
    cq = lcx.CompletionQueue()
    deadline = 16
    lcx.put_x(jnp.float32(1.0)).remote_comp(cq).max_retries(3) \
        .timeout(deadline)()
    t0 = time.perf_counter()
    statuses = []
    for tick in range(deadline + 1):
        lcx.progress()
        evs = cq.pop_all()
        if evs:
            statuses = [ev.status.value for ev in evs]
            break
    dt = time.perf_counter() - t0
    assert statuses, "unrecoverable transfer never completed: hang"
    assert statuses[0] in ("fatal", "timeout"), statuses
    assert tick <= deadline, f"surfaced after deadline: tick {tick}"
    assert not lcx.runtime().has_inflight()
    return {"kind": "unrecoverable", "rate": 1.0, "tasks": 1,
            "seconds": dt, "ticks_to_surface": tick,
            "status": statuses[0]}


def main(argv: List[str] = None) -> List[Dict[str, float]]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--n", type=int, default=None,
                    help="override tasks per cell")
    ap.add_argument("--out", type=str, default=None,
                    help="write the JSON rows here")
    args = ap.parse_args(argv)

    n = args.n if args.n is not None else (16 if args.smoke else 64)
    rates = (0.01, 0.1) if args.smoke else (0.01, 0.02, 0.05, 0.1)

    rows: List[Dict[str, float]] = []
    print(f"{'kind':14s} {'rate':>6s} {'tasks':>6s} {'ms':>8s} "
          f"{'progress':>9s} {'faults':>7s} {'retries':>8s}")
    for kind in ("drop", "delay", "duplicate"):
        for rate in rates:
            r = run_cell(kind, rate, n, args.seed)
            rows.append(r)
            print(f"{r['kind']:14s} {r['rate']:6.2f} {r['tasks']:6d} "
                  f"{r['seconds'] * 1e3:8.2f} {r['progress_calls']:9d} "
                  f"{r['faults']:7d} {r['retries']:8d}")
    r = run_unrecoverable(args.seed)
    rows.append(r)
    print(f"{r['kind']:14s} {r['rate']:6.2f} {r['tasks']:6d} "
          f"{r['seconds'] * 1e3:8.2f} "
          f"-> {r['status']} after {r['ticks_to_surface']} ticks")
    print("all cells converged")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.out}")
    print("CHAOSBENCH_JSON=" + json.dumps(rows))
    return rows


if __name__ == "__main__":
    main()
