"""Paper Fig. 1 reproduction: multithreaded ping-pong → concurrent-lane
ping-pong on the TPU execution model.

The paper measures aggregated unidirectional 8-byte message rate between
two nodes with 1..128 processes/threads per node.  In SPMD there are no
runtime threads; the analogue of "N threads concurrently posting
fine-grained messages" is N independent in-flight transfer lanes inside
one step (DESIGN.md §2).  We sweep lanes ∈ {1..128} and compare:

- ``mpi-like``  — one matched blocking transfer per message, serialized
  by a data-dependency chain (BSP-style single-threaded rank);
- ``lcx``       — N asynchronous lanes posted independently, one
  explicit progress (per-lane completion objects; the scheduler
  interleaves);
- ``lcx+pool``  — N lanes with packet-pool aggregation: all eager
  messages ride ONE packed transfer (doorbell batching).

Runs under ``shard_map`` over two devices so transfers lower to real
``collective-permute`` HLO ops; the parent benchmark process keeps a
single device, so this module re-execs itself in a subprocess with
``--xla_force_host_platform_device_count=2``.

Reported per design: wall-clock msg rate (CPU-device proxy) and the
number of collective ops in the compiled HLO (the hardware-independent
structural cost; on Slingshot the paper's LCI2 ≈ LCI1 ≫ MPI ordering
tracks this op count and the serialization between ops).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

LANES = (1, 2, 4, 8, 16, 32, 64, 128)
DESIGNS = ("mpi-like", "lcx", "lcx+pool")
N_RANKS = 2
MSG_WORDS = 2        # 8-byte messages
REPEAT = 50


def _pingpong_body(design: str, lanes: int):
    import jax.numpy as jnp
    import repro.core as lcx

    def body(x):
        lcx.init()
        pool = lcx.PacketPool(packet_size=1 << 16,
                              aggregate=(design == "lcx+pool"))
        dev = lcx.Device(axis="x")
        peer = lcx.Perm.shift(1)
        x = x[0]
        payloads = [x + i for i in range(lanes)]
        if design == "mpi-like":
            out = []
            carry = jnp.zeros_like(x)
            for i in range(lanes):
                sync = lcx.Synchronizer(threshold=1)
                lcx.put_x(payloads[i] + carry * 0).tag(i) \
                    .perm(peer).remote_comp(sync).device(dev) \
                    .allow_aggregation(False)()
                lcx.progress_x().pool(pool)()
                (ev,) = sync.wait()
                carry = ev.payload          # serializes the next lane
                out.append(ev.payload)
            return sum(out)[None]
        syncs = [lcx.Synchronizer(threshold=1) for _ in range(lanes)]
        for i in range(lanes):
            lcx.put_x(payloads[i]).tag(i).perm(peer) \
                .remote_comp(syncs[i]).device(dev)()
        lcx.progress_x().pool(pool)()
        return sum(s.wait()[0].payload for s in syncs)[None]

    return body


def _chain_depth(hlo: str) -> int:
    """Longest dependency chain of collective ops in the entry
    computation — the serialization structure the paper's MPI-vs-LCI
    comparison is really about (depth=lanes: blocking/BSP; depth=1:
    fully asynchronous lanes)."""
    import re
    defs = {}
    is_coll = set()
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=", line)
        if not m:
            continue
        name = m.group(1)
        defs[name] = re.findall(r"%([\w.\-]+)", line)[1:]
        if re.search(r"\b(collective-permute|all-to-all)(-start)?\(",
                     line):
            is_coll.add(name)
    memo = {}

    def depth(n):
        if n in memo:
            return memo[n]
        memo[n] = 0
        d = max((depth(op) for op in defs.get(n, ())), default=0)
        memo[n] = d + (1 if n in is_coll else 0)
        return memo[n]

    return max((depth(n) for n in is_coll), default=0)


def _run_design_inproc(design: str, lanes: int) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map

    mesh = make_mesh((N_RANKS,), ("x",))
    body = _pingpong_body(design, lanes)
    fn = jax.jit(shard_map(body, mesh, in_specs=P("x", None),
                           out_specs=P("x", None)))
    xs = jnp.arange(N_RANKS * MSG_WORDS,
                    dtype=jnp.float32).reshape(N_RANKS, MSG_WORDS)
    compiled = fn.lower(xs).compile()
    hlo = compiled.as_text()
    n_coll = sum(hlo.count(f" {k}(") + hlo.count(f"{k}-start(")
                 for k in ("collective-permute", "all-to-all",
                           "all-gather", "all-reduce"))
    depth = _chain_depth(hlo)
    out = fn(xs)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(REPEAT):
        out = fn(xs)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / REPEAT
    return {"design": design, "lanes": lanes, "us_per_call": dt * 1e6,
            "msgs_per_s": lanes / dt, "hlo_collectives": n_coll,
            "chain_depth": depth}


def _child() -> None:
    rows = []
    for lanes in LANES:
        for design in DESIGNS:
            rows.append(_run_design_inproc(design, lanes))
    print("PINGPONG_JSON=" + json.dumps(rows))


def main(out_csv: str = None) -> List[Dict[str, float]]:
    import jax
    if len(jax.devices()) >= N_RANKS:
        rows = []
        for lanes in LANES:
            for design in DESIGNS:
                rows.append(_run_design_inproc(design, lanes))
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count=2")
        env["PINGPONG_CHILD"] = "1"
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__)],
            capture_output=True, text=True, env=env, timeout=1200)
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-2000:])
        line = [l for l in out.stdout.splitlines()
                if l.startswith("PINGPONG_JSON=")][0]
        rows = json.loads(line[len("PINGPONG_JSON="):])

    print(f"{'design':10s} {'lanes':>6s} {'us/call':>10s} "
          f"{'Mmsg/s':>8s} {'n_coll':>7s} {'depth':>6s}")
    for r in rows:
        print(f"{r['design']:10s} {r['lanes']:6d} "
              f"{r['us_per_call']:10.1f} "
              f"{r['msgs_per_s']/1e6:8.3f} {r['hlo_collectives']:7d} "
              f"{r.get('chain_depth', 0):6d}")
    if out_csv:
        import csv
        with open(out_csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return rows


if __name__ == "__main__":
    if os.environ.get("PINGPONG_CHILD"):
        _child()
    else:
        main()
