"""Progress-engine fast-path benchmark: post+match throughput and
per-device ledger drain.

The LCI papers attribute multithreaded message-rate to hash-table tag
matching; this benchmark measures the trace-time analogue.  It sweeps
pending-op depth across matching kinds/policies and compares the keyed
hash-bucket engine (``repro.core.resources.MatchingEngine``) against a
faithful reimplementation of the pre-optimization O(S×R) scan engine
(``LegacyScanEngine`` below — the "before" in the emitted JSON).

Workload per (kind, policy, depth D): post D sends with distinct keys
(building pending depth D), then D recvs in *reverse* key order (the
out-of-order arrival pattern map-mode matching exists for).  Throughput
is total posts / wall time.  The legacy engine is O(S×R) per post here,
so it is only run up to ``--legacy-max-depth`` (default 4096) to keep
runtimes sane; the keyed engine runs the full sweep.

A second section measures ``Runtime.take_ready(device)``: per-device
ledger pop (new) vs the old quadratic filter over one global list.

Emits ``BENCH_progress.json`` (``--out``) with before/after rows;
``--smoke`` trims depths for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.core as lcx
from repro.core.resources import MatchingEngine, PostedOp

DEPTHS = (64, 256, 1024, 4096, 8192)
MATRIX: Tuple[Tuple[str, str], ...] = (
    ("map", "none"),
    ("map", "tag_only"),
    ("map", "rank_only"),
    ("map", "rank_tag"),
    ("map", "custom"),
    ("queue", "tag_only"),
)


class LegacyScanEngine:
    """The pre-optimization matching engine: one pending list per side,
    full O(S×R) rescan (with per-comparison key recomputation) after
    every post.  Kept here as the benchmark baseline — do not use."""

    def __init__(self, kind: str = "map", policy: str = "rank_tag",
                 key_fn=None) -> None:
        self.kind = kind
        self.policy = policy
        self.key_fn = key_fn
        self._pending_send: deque = deque()
        self._pending_recv: deque = deque()
        self.n_matched = 0

    def _key(self, op: PostedOp) -> Any:
        policy = self.policy
        axis_size = op.device.axis_size
        if policy == "none":
            return ()
        if policy == "rank_only":
            return tuple(sorted(op.perm.pairs_for(axis_size))) \
                if op.perm else ()
        if policy == "tag_only":
            return op.tag
        if policy == "rank_tag":
            return ((tuple(sorted(op.perm.pairs_for(axis_size)))
                     if op.perm else ()), op.tag)
        return self.key_fn(op)

    def post(self, op: PostedOp) -> List[Tuple[PostedOp, PostedOp]]:
        if op.kind == "send":
            self._pending_send.append(op)
        else:
            self._pending_recv.append(op)
        return self._drain()

    def _drain(self) -> List[Tuple[PostedOp, PostedOp]]:
        matches: List[Tuple[PostedOp, PostedOp]] = []
        if self.kind == "queue":
            while self._pending_send and self._pending_recv:
                s, r = self._pending_send[0], self._pending_recv[0]
                if self._key(s) != self._key(r):
                    break
                self._pending_send.popleft()
                self._pending_recv.popleft()
                matches.append((s, r))
        else:
            changed = True
            while changed:
                changed = False
                for s in list(self._pending_send):
                    ks = self._key(s)
                    for r in list(self._pending_recv):
                        if ks == self._key(r):
                            self._pending_send.remove(s)
                            self._pending_recv.remove(r)
                            matches.append((s, r))
                            changed = True
                            break
                    if changed:
                        break
        self.n_matched += len(matches)
        return matches


def _make_ops(policy: str, depth: int,
              device: lcx.Device) -> Tuple[List[PostedOp], List[PostedOp]]:
    """D sends with distinct keys plus matching recvs in reverse order."""
    perms = None
    if policy in ("rank_only", "rank_tag"):
        perms = [lcx.Perm.pairs([(0, i)]) for i in range(depth)]

    def op(kind: str, i: int, seq: int) -> PostedOp:
        return PostedOp(kind=kind, buffer=None,
                        perm=perms[i] if perms else None,
                        tag=i, comp=None, device=device, seq=seq)

    if policy == "none":
        # every op has the same key; depth still builds because all the
        # sends are posted before any recv
        sends = [op("send", 0, i) for i in range(depth)]
        recvs = [op("recv", 0, depth + i) for i in range(depth)]
        return sends, recvs
    sends = [op("send", i, i) for i in range(depth)]
    order = range(depth) if policy == "queue-inorder" else \
        range(depth - 1, -1, -1)
    recvs = [op("recv", i, depth + i) for i in order]
    return sends, recvs


def _engine(cls, kind: str, policy: str):
    key_fn = (lambda o: o.tag) if policy == "custom" else None
    eng_policy = "custom" if policy == "custom" else policy
    return cls(kind=kind, policy=eng_policy, key_fn=key_fn)


def bench_post_match(kind: str, policy: str, depth: int,
                     legacy: bool) -> Optional[Dict[str, Any]]:
    device = lcx.Device(axis="x", mesh_shape={"x": 2})
    # queue mode only matches in order; reverse recvs would just pend
    sends, recvs = _make_ops(
        "queue-inorder" if kind == "queue" else policy, depth, device)
    if kind == "queue":
        s2, _ = _make_ops(policy if policy != "custom" else "tag_only",
                          depth, device)
        for a, b in zip(sends, s2):
            a.perm = b.perm
    cls = LegacyScanEngine if legacy else MatchingEngine
    eng = _engine(cls, kind, policy)
    n_ops = 2 * depth
    t0 = time.perf_counter()
    for s in sends:
        eng.post(s)
    for r in recvs:
        eng.post(r)
    dt = time.perf_counter() - t0
    if eng.n_matched != depth:
        raise AssertionError(
            f"{'legacy' if legacy else 'keyed'} {kind}/{policy} depth "
            f"{depth}: matched {eng.n_matched}, expected {depth}")
    return {"kind": kind, "policy": policy, "depth": depth,
            "engine": "legacy-scan" if legacy else "keyed",
            "seconds": dt, "ops_per_s": n_ops / max(dt, 1e-12)}


class LegacyLedger:
    """Pre-optimization global ready list with quadratic filtering."""

    def __init__(self) -> None:
        self._ready: List[Tuple[PostedOp, PostedOp]] = []

    def enqueue_matches(self, matches) -> None:
        self._ready.extend(matches)

    def take_ready(self, device=None):
        if device is None:
            out, self._ready = self._ready, []
            return out
        out = [m for m in self._ready
               if m[0].device is device or m[1].device is device]
        self._ready = [m for m in self._ready if m not in out]
        return out


def bench_take_ready(n_devices: int, per_device: int,
                     legacy: bool) -> Dict[str, Any]:
    devices = [lcx.Device(axis="x", mesh_shape={"x": 2})
               for _ in range(n_devices)]
    ledger = LegacyLedger() if legacy else lcx.init()
    seq = 0
    for i in range(per_device):
        for d in devices:
            s = PostedOp(kind="send", buffer=None, perm=None, tag=i,
                         comp=None, device=d, seq=seq)
            r = PostedOp(kind="recv", buffer=None, perm=None, tag=i,
                         comp=None, device=d, seq=seq)
            seq += 1
            ledger.enqueue_matches([(s, r)])
    t0 = time.perf_counter()
    total = 0
    for d in devices:
        total += len(ledger.take_ready(d))
    dt = time.perf_counter() - t0
    if total != n_devices * per_device:
        raise AssertionError(f"ledger drained {total} matches, expected "
                             f"{n_devices * per_device}")
    return {"n_devices": n_devices, "per_device": per_device,
            "engine": "legacy-list" if legacy else "per-device",
            "seconds": dt, "matches_per_s": total / max(dt, 1e-12)}


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small depths for CI sanity")
    ap.add_argument("--depths", type=int, nargs="*", default=None)
    ap.add_argument("--legacy-max-depth", type=int, default=4096,
                    help="skip the O(S×R) baseline above this depth")
    ap.add_argument("--out", type=str, default="BENCH_progress.json")
    args = ap.parse_args(argv)

    depths = tuple(args.depths) if args.depths else \
        ((64, 256) if args.smoke else DEPTHS)
    lcx.init()

    rows: List[Dict[str, Any]] = []
    print(f"{'kind':6s} {'policy':10s} {'depth':>6s} "
          f"{'keyed Mops/s':>13s} {'legacy Mops/s':>14s} {'speedup':>8s}")
    for kind, policy in MATRIX:
        for depth in depths:
            new = bench_post_match(kind, policy, depth, legacy=False)
            old = None
            if depth <= args.legacy_max_depth:
                old = bench_post_match(kind, policy, depth, legacy=True)
            row = dict(new)
            row["legacy_ops_per_s"] = old["ops_per_s"] if old else None
            row["legacy_seconds"] = old["seconds"] if old else None
            row["speedup"] = (new["ops_per_s"] / old["ops_per_s"]
                              if old else None)
            rows.append(row)
            print(f"{kind:6s} {policy:10s} {depth:6d} "
                  f"{new['ops_per_s'] / 1e6:13.3f} "
                  f"{(old['ops_per_s'] / 1e6) if old else float('nan'):14.3f} "
                  f"{row['speedup'] if row['speedup'] else float('nan'):8.1f}")

    ledger_rows: List[Dict[str, Any]] = []
    n_dev, per_dev = (4, 64) if args.smoke else (8, 2048)
    for legacy in (False, True):
        ledger_rows.append(bench_take_ready(n_dev, per_dev, legacy))
    spd = (ledger_rows[0]["matches_per_s"] /
           max(ledger_rows[1]["matches_per_s"], 1e-12))
    print(f"take_ready({n_dev} devices x {per_dev}): per-device "
          f"{ledger_rows[0]['matches_per_s'] / 1e6:.3f} Mmatch/s vs legacy "
          f"{ledger_rows[1]['matches_per_s'] / 1e6:.3f} Mmatch/s "
          f"({spd:.1f}x)")

    out = {"post_match": rows, "take_ready": ledger_rows,
           "smoke": bool(args.smoke)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    print("MATCHBENCH_JSON=" + json.dumps(
        {"n_rows": len(rows),
         "min_speedup": min((r["speedup"] for r in rows if r["speedup"]),
                            default=None)}))
    return out


if __name__ == "__main__":
    main()
