"""Device-isolation benchmark: per-device post+match throughput with and
without a second busy device.

The point of the resource hierarchy (paper feature (b), and the
HPX+LCI / LCI-performance papers' per-thread-device results) is that a
library or thread posting on its *own* device must not contend with
another device's traffic — no shared matching-engine buckets, no shared
transfer ledger scans.

Workload: a "foreground" device posts D send/recv pairs (distinct tags,
recvs in reverse order) and drains them with per-device progress.  We
measure foreground ops/s in three configurations:

1. ``solo``          — foreground device alone on its runtime.
2. ``busy-neighbor`` — a second isolated device on the SAME runtime
   carries ``load×D`` pre-posted pending pairs the whole time.
3. ``shared-legacy`` — the "before" picture: foreground and the same
   busy load share ONE engine + ledger (two floating devices on the
   global-style defaults), so the neighbor's pending ops sit in the
   same buckets and ledger.

Isolation holds when (2) tracks (1) (ratio ~1.0) while (3) degrades.
Emits ``BENCH_isolation.json``; ``--smoke`` trims depths for CI.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time
from typing import Any, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.core as lcx

DEPTHS = (256, 1024, 4096)


class _FakeBuf:
    """Shape/dtype carrier — keeps the benchmark allocation-free."""

    shape = (8,)
    dtype = np.float32


def _post_pairs(n: int, *, device, tag0: int = 0) -> None:
    buf = _FakeBuf()
    for i in range(n):
        lcx.send_x(buf).tag(tag0 + i).device(device)()
    for i in reversed(range(n)):
        lcx.recv_x(buf).tag(tag0 + i).device(device)()


def _drain(device) -> None:
    # loopback devices: transfers land in one progress call
    lcx.progress_x().device(device)()


def bench_foreground(depth: int, mode: str, load: int) -> Dict[str, Any]:
    """Time `depth` foreground post+match+progress ops under `mode`."""
    rt = lcx.Runtime(name=f"iso-{mode}")
    if mode == "shared-legacy":
        # two floating devices sharing the runtime's default engine and
        # device-less ledger — the pre-hierarchy contention picture
        fg = rt.default_device
        neighbor = rt.default_device
    else:
        fg = rt.device(name="fg")
        neighbor = rt.device(name="bg") if mode == "busy-neighbor" else None
    if mode != "solo" and neighbor is not None:
        # park load*depth matched-but-unprogressed pairs on the neighbor
        if mode == "shared-legacy":
            _post_pairs(load * depth, device=neighbor, tag0=10_000_000)
        else:
            _post_pairs(load * depth, device=neighbor)
    # GC off inside the timed region: cyclic-collector sweeps over the
    # neighbor's parked PostedOps would otherwise bill the *collector*'s
    # O(live objects) to the foreground and mask the engine's behaviour.
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        _post_pairs(depth, device=fg)
        _drain(fg)
        dt = time.perf_counter() - t0
    finally:
        gc.enable()
    n_ops = 2 * depth + 1
    # neighbor load stays pending the whole run (that is the point);
    # clean it up outside the timed region
    rt.finalize(strict=False)
    return {"mode": mode, "depth": depth, "seconds": dt,
            "ops_per_s": n_ops / dt}


def main(argv: List[str] | None = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--load", type=int, default=4,
                    help="neighbor pending load as a multiple of depth")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_isolation.json")
    args = ap.parse_args(argv)

    lcx.init()
    depths = (64, 256) if args.smoke else DEPTHS
    rows: List[Dict[str, Any]] = []
    print(f"{'depth':>6} {'solo Mops/s':>12} {'busy-nbr':>10} "
          f"{'shared':>10} {'iso ratio':>10}")
    for depth in depths:
        best: Dict[str, Dict[str, Any]] = {}
        for mode in ("solo", "busy-neighbor", "shared-legacy"):
            runs = [bench_foreground(depth, mode, args.load)
                    for _ in range(args.repeats)]
            best[mode] = max(runs, key=lambda r: r["ops_per_s"])
        ratio = (best["busy-neighbor"]["ops_per_s"]
                 / best["solo"]["ops_per_s"])
        shared_ratio = (best["shared-legacy"]["ops_per_s"]
                        / best["solo"]["ops_per_s"])
        row = {"depth": depth, "load": args.load,
               "solo_ops_per_s": best["solo"]["ops_per_s"],
               "busy_neighbor_ops_per_s":
                   best["busy-neighbor"]["ops_per_s"],
               "shared_legacy_ops_per_s":
                   best["shared-legacy"]["ops_per_s"],
               "isolation_ratio": ratio,
               "shared_ratio": shared_ratio}
        rows.append(row)
        print(f"{depth:6d} {row['solo_ops_per_s'] / 1e6:12.3f} "
              f"{row['busy_neighbor_ops_per_s'] / 1e6:10.3f} "
              f"{row['shared_legacy_ops_per_s'] / 1e6:10.3f} "
              f"{ratio:10.2f}")

    out = {"rows": rows, "smoke": bool(args.smoke), "load": args.load,
           "repeats": args.repeats}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    worst = min(r["isolation_ratio"] for r in rows)
    print("ISOLATIONBENCH_JSON=" + json.dumps(
        {"worst_isolation_ratio": worst,
         "depths": [r["depth"] for r in rows]}))
    lcx.finalize(strict=False)
    return out


if __name__ == "__main__":
    main()
