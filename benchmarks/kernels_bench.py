"""Per-kernel correctness/latency table: Pallas (interpret on CPU — a
correctness proxy, not TPU timing) vs the pure-XLA oracle.  The TPU
story for each kernel is in EXPERIMENTS.md §Roofline (VMEM working sets
and MXU-aligned block shapes from the BlockSpecs)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref as kref


def _time(fn, *args, repeat=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat, out


def bench_flash() -> Dict[str, float]:
    b, hq, hkv, s, d = 1, 8, 2, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    t_ref, o_ref = _time(jax.jit(
        lambda *a: kref.flash_attention_ref(*a, causal=True)), q, k, v)
    t_pal, o_pal = _time(jax.jit(
        lambda *a: ops.flash_attention(*a, causal=True,
                                       backend="pallas")), q, k, v)
    err = float(jnp.abs(o_ref - o_pal).max())
    return {"kernel": "flash_attention", "xla_us": t_ref * 1e6,
            "pallas_interp_us": t_pal * 1e6, "max_err": err}


def bench_ssd() -> Dict[str, float]:
    b, s, h, p, n = 1, 512, 4, 32, 16
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, n))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, h, n))
    t_ref, (y_ref, _) = _time(jax.jit(
        lambda *a: ops.ssd_scan(*a, chunk=128, backend="xla")),
        x, dt, A, Bm, Cm)
    t_pal, (y_pal, _) = _time(jax.jit(
        lambda *a: ops.ssd_scan(*a, chunk=128, backend="pallas")),
        x, dt, A, Bm, Cm)
    err = float(jnp.abs(y_ref - y_pal).max())
    return {"kernel": "ssd_scan", "xla_us": t_ref * 1e6,
            "pallas_interp_us": t_pal * 1e6, "max_err": err}


def bench_gmm() -> Dict[str, float]:
    e, c, d, f = 8, 256, 256, 512
    xb = jax.random.normal(jax.random.PRNGKey(0), (e, c, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (e, d, f))
    t_ref, o_ref = _time(jax.jit(kref.moe_gmm_ref), xb, w)
    t_pal, o_pal = _time(jax.jit(
        lambda *a: ops.moe_gmm(*a, backend="pallas")), xb, w)
    err = float(jnp.abs(o_ref - o_pal).max() / jnp.abs(o_ref).max())
    return {"kernel": "moe_gmm", "xla_us": t_ref * 1e6,
            "pallas_interp_us": t_pal * 1e6, "max_err": err}


def main(out_csv: str = None) -> List[Dict[str, float]]:
    rows = [bench_flash(), bench_ssd(), bench_gmm()]
    print(f"{'kernel':18s} {'xla_us':>10s} {'interp_us':>11s} "
          f"{'max_err':>9s}")
    for r in rows:
        print(f"{r['kernel']:18s} {r['xla_us']:10.1f} "
              f"{r['pallas_interp_us']:11.1f} {r['max_err']:9.2e}")
    if out_csv:
        import csv
        with open(out_csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
