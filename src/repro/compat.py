"""JAX version compatibility shims.

The repo targets the newest JAX API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``lax.axis_size``, positional
``AbstractMesh(shape, names)``); the pinned toolchain may carry an older
release where those spell differently.  Every module that touches one of
the moving APIs goes through this file so version drift is absorbed in
exactly one place.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax import lax

__all__ = ["AxisType", "abstract_mesh", "axis_size", "make_mesh",
           "shard_map"]

# Partitionable threefry makes sharded RNG output independent of the
# device layout, so sharded param init bit-matches single-device init.
# Newer JAX defaults this on; the pinned release defaults it off, which
# silently diverges multi-host init from the eager reference.
if hasattr(jax.config, "jax_threefry_partitionable") \
        and not jax.config.jax_threefry_partitionable:
    jax.config.update("jax_threefry_partitionable", True)


try:  # JAX >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    class AxisType:  # type: ignore[no-redef]
        """Placeholder for jax.sharding.AxisType on older JAX (where all
        mesh axes behave like ``Auto``)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def axis_size(axis: str) -> int:
    """Size of a bound mesh/vmap axis, from inside shard_map/vmap.

    ``lax.axis_size`` only exists on newer JAX; ``psum`` of a unit
    constant folds to the same number everywhere.
    """
    if hasattr(lax, "axis_size"):
        return int(lax.axis_size(axis))
    return int(lax.psum(1, axis))


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              axis_types: Optional[Tuple[Any, ...]] = None):
    """``jax.make_mesh`` across the axis_types signature change."""
    try:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_types or
                                         (AxisType.Auto,) * len(axes)))
    except TypeError:  # old signature: no axis_types kwarg
        return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.sharding.AbstractMesh`` across its signature change:
    new JAX takes ``(shape, names)``; old JAX takes one tuple of
    ``(name, size)`` pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def shard_map(f, mesh, in_specs, out_specs, check: bool = False,
              axis_names: Optional[set] = None):
    """``jax.shard_map`` / ``jax.experimental.shard_map`` with the
    ``check_vma``/``check_rep`` rename and the ``axis_names``/``auto``
    partial-manual spelling absorbed."""
    if hasattr(jax, "shard_map"):
        kw: Dict[str, Any] = {"check_vma": check}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {"check_rep": check}
    if axis_names is not None:
        # old spelling: list the *auto* (non-manual) axes instead
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
