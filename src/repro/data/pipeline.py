"""Deterministic synthetic token pipeline.

Every batch is a pure function of ``(seed, step)`` so any worker (or a
restarted worker after a failure) regenerates exactly the same data —
the property the checkpoint/restart path relies on.  Batches are laid
out directly with the trainer's NamedSharding via
``jax.make_array_from_callback`` so each device only materializes its
own shard (no host-side global batch at scale).

The "dataset" is a Zipf-ish token stream with a short Markov flavor so
the loss actually decreases during the example runs (pure uniform noise
has constant optimal loss).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class SyntheticLMDataset:
    """Stateless: ``batch(step)`` -> dict of numpy arrays."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frontend_len: int = 0,
                 frontend_dim: int = 0, family: str = "dense") -> None:
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.frontend_len = frontend_len
        self.frontend_dim = frontend_dim
        self.family = family
        # fixed Markov transition "structure" derived from the seed
        rng = np.random.default_rng(seed)
        self._shift = rng.integers(1, max(vocab - 1, 2))

    def _tokens(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch at ``step``.  Each ROW is a
        pure function of (seed, step, global_row) so any worker
        regenerating any slice gets bit-identical data — the
        restart/reshard invariant."""
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r]))
            # Zipf-distributed tokens with a deterministic Markov overlay
            z = rng.zipf(1.3, size=self.seq_len)
            base = (z % self.vocab).astype(np.int32)
            flip = rng.random(self.seq_len) < 0.5
            markov = (np.roll(base, 1) + self._shift) % self.vocab
            rows.append(np.where(flip, markov, base).astype(np.int32))
        return np.stack(rows)

    def batch(self, step: int, lo: int = 0, hi: Optional[int] = None
              ) -> Dict[str, np.ndarray]:
        hi = self.global_batch if hi is None else hi
        toks = self._tokens(step, lo, hi)
        out: Dict[str, np.ndarray] = {
            "tokens": toks,
            "labels": np.roll(toks, -1, axis=1),
        }
        flen = self.seq_len if self.family == "audio" else \
            self.frontend_len
        if self.family == "audio" or self.frontend_len:
            fe = []
            for r in range(lo, hi):
                rng = np.random.default_rng(
                    np.random.SeedSequence([self.seed, step, r, 7]))
                fe.append(rng.standard_normal(
                    (flen, self.frontend_dim), dtype=np.float32))
            out["frontend"] = np.stack(fe)
        return out


def batch_specs(cfg: Any, seq_len: int, global_batch: int,
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if cfg.family == "audio":
        specs = {
            "tokens": jax.ShapeDtypeStruct((global_batch, seq_len),
                                           jnp.int32),
            "labels": jax.ShapeDtypeStruct((global_batch, seq_len),
                                           jnp.int32),
            "frontend": jax.ShapeDtypeStruct(
                (global_batch, seq_len, cfg.d_model), cfg.dtype),
        }
    elif cfg.frontend_len:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend_len, cfg.d_model), cfg.dtype)
    return specs


def make_batch(cfg: Any, seq_len: int, global_batch: int, step: int = 0,
               seed: int = 0) -> Dict[str, np.ndarray]:
    ds = SyntheticLMDataset(
        cfg.vocab, seq_len, global_batch, seed=seed,
        frontend_len=cfg.frontend_len, frontend_dim=cfg.d_model,
        family=cfg.family)
    return ds.batch(step)


class DataLoader:
    """Prefetching loader that materializes each device's shard directly.

    ``shardings`` maps input name -> NamedSharding (from the trainer).
    A background thread keeps ``prefetch`` batches ready.
    """

    def __init__(self, dataset: SyntheticLMDataset,
                 shardings: Dict[str, NamedSharding],
                 start_step: int = 0, prefetch: int = 2) -> None:
        self.dataset = dataset
        self.shardings = shardings
        self.step = start_step
        self.prefetch = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _device_batch(self, step: int) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        full_cache: Dict[str, np.ndarray] = {}

        for name, sharding in self.shardings.items():
            spec_like = self.dataset.batch(step, 0, 1)[name]
            gshape = (self.dataset.global_batch,) + spec_like.shape[1:]

            def cb(index, *, _name=name, _step=step):
                rows = index[0]
                lo = rows.start or 0
                hi = rows.stop if rows.stop is not None \
                    else self.dataset.global_batch
                if (_name, lo, hi) not in full_cache:
                    full_cache[(_name, lo, hi)] = \
                        self.dataset.batch(_step, lo, hi)[_name]
                arr = full_cache[(_name, lo, hi)]
                rest = tuple(index[1:])
                return arr[(slice(None),) + rest]

            out[name] = jax.make_array_from_callback(gshape, sharding, cb)
        return out

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            try:
                batch = self._device_batch(step)
            except Exception as e:  # surface in the consumer
                self._q.put(e)
                return
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, jax.Array]]]:
        return self

    def __next__(self) -> Tuple[int, Dict[str, jax.Array]]:
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
