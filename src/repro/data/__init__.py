from .pipeline import (SyntheticLMDataset, DataLoader, batch_specs,
                       make_batch)

__all__ = ["SyntheticLMDataset", "DataLoader", "batch_specs", "make_batch"]
