"""Mamba-2 (SSD — state-space duality) mixer.

Prefill/train uses the chunked SSD algorithm: within-chunk quadratic
(attention-like) term + inter-chunk state recurrence carried by a
``lax.scan`` over chunks.  All decay arithmetic is in f32; the decays are
``exp`` of non-positive sums so they never overflow.

Decode carries ``(conv_state [B, k-1, conv_ch], ssm_state [B, H, N, P])``
and costs O(1) per token — this is why the ``long_500k`` cell is
admissible for SSM/hybrid architectures.

The Pallas kernel (`repro.kernels.ssd_scan`) implements the within-chunk
term with MXU-aligned blocking; this module is the pure-XLA baseline and
the oracle the kernel is validated against.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import PyTree, dense, dense_init, merge, rmsnorm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def ssm_init(key: jax.Array, cfg: Any) -> Tuple[PyTree, PyTree]:
    D = cfg.d_model
    di = cfg.ssm_d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * G * N
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * G * N + H
    parts = [
        ("in_proj", dense_init(ks[0], D, in_dim, dims=("embed", "ssm_in"),
                               dtype=cfg.param_dtype)),
        ("out_proj", dense_init(ks[1], di, D, dims=("ssm_inner", "embed"),
                                scale=1.0 / math.sqrt(di),
                                dtype=cfg.param_dtype)),
    ]
    params, dims = merge(*parts)
    params["conv_w"] = (jax.random.normal(ks[2], (cfg.ssm_conv, conv_ch),
                                          jnp.float32)
                        * (1.0 / math.sqrt(cfg.ssm_conv))).astype(
                            cfg.param_dtype)
    dims["conv_w"] = ("conv_k", "ssm_conv_ch")
    params["conv_b"] = jnp.zeros((conv_ch,), cfg.param_dtype)
    dims["conv_b"] = ("ssm_conv_ch",)
    params["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32))
    dims["A_log"] = ("ssm_heads",)
    params["D"] = jnp.ones((H,), jnp.float32)
    dims["D"] = ("ssm_heads",)
    params["dt_bias"] = jnp.log(
        jnp.exp(jnp.linspace(1e-3, 0.1, H, dtype=jnp.float32)) - 1.0)
    dims["dt_bias"] = ("ssm_heads",)
    params["norm_g"] = jnp.ones((di,), cfg.param_dtype)
    dims["norm_g"] = ("ssm_inner",)
    return params, dims


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------
def _split_proj(cfg: Any, zxbcdt: jax.Array):
    di, G, N, H = (cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state,
                   cfg.ssm_heads)
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di: 2 * di]
    Bc = zxbcdt[..., 2 * di: 2 * di + G * N]
    Cc = zxbcdt[..., 2 * di + G * N: 2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, jnp.concatenate([x, Bc, Cc], axis=-1), dt, (di, G, N, H)


def _causal_conv(p: PyTree, xbc: jax.Array) -> jax.Array:
    """Depthwise causal conv over S.  xbc [B, S, C]."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :]
              * p["conv_w"][i].astype(xbc.dtype) for i in range(k))
    return jax.nn.silu((out + p["conv_b"].astype(xbc.dtype)
                        ).astype(jnp.float32)).astype(xbc.dtype)


def _heads(cfg: Any, xbc: jax.Array):
    """split conv output into x [B,S,H,P], B/C expanded to heads."""
    di, G, N, H = (cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state,
                   cfg.ssm_heads)
    b, s, _ = xbc.shape
    P = cfg.ssm_head_dim
    x = xbc[..., :di].reshape(b, s, H, P)
    Bm = xbc[..., di: di + G * N].reshape(b, s, G, N)
    Cm = xbc[..., di + G * N:].reshape(b, s, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)
    return x, Bm, Cm


# ---------------------------------------------------------------------------
# chunked SSD (full sequence)
# ---------------------------------------------------------------------------
def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,H,P]; dt [B,S,H] (post-softplus); A [H] (negative);
    B/C [B,S,H,N].  Returns (y [B,S,H,P], h_final [B,H,N,P]).

    Three-phase SSD (the parallel decomposition from the Mamba-2 paper):
    1. per-chunk quadratic term + chunk states — VMAPPED over chunks
       (shardable over the sequence/model axis);
    2. inter-chunk state recurrence — a tiny sequential scan over
       [B,H,N,P] states only (no matmuls);
    3. per-chunk offset contribution from the carried state — vmapped.
    """
    from repro.parallel.sharding import constrain
    b, s, H, P = x.shape
    N = Bm.shape[-1]
    cs = min(chunk, s)
    while s % cs:
        cs //= 2
    nc = s // cs
    f32 = jnp.float32
    cdims = ("attn_chunks", "batch", None, None, None)

    def chunkify(t):
        out = t.reshape((b, nc, cs) + t.shape[2:]).swapaxes(0, 1)
        return constrain(out, cdims[: out.ndim])

    xs, dts, Bs, Cs = map(chunkify, (x, dt, Bm, Cm))
    if h0 is None:
        h0 = jnp.zeros((b, H, N, P), f32)

    # -- phase 1: per-chunk diag term + chunk state (parallel) ----------
    def chunk_fwd(xc, dtc, Bc, Cc):
        dtc = dtc.astype(f32)
        dA = dtc * A                                # [b,cs,H] (<= 0)
        cum = jnp.cumsum(dA, axis=1)
        cum_last = cum[:, -1:, :]                   # [b,1,H]
        scores = jnp.einsum("bihn,bjhn->bhij", Cc.astype(f32),
                            Bc.astype(f32))
        Lmat = jnp.exp(cum.transpose(0, 2, 1)[:, :, :, None]
                       - cum.transpose(0, 2, 1)[:, :, None, :])
        tri = jnp.tril(jnp.ones((cs, cs), bool))
        Lmat = jnp.where(tri[None, None], Lmat, 0.0)
        w = scores * Lmat * dtc.transpose(0, 2, 1)[:, :, None, :]
        y_diag = jnp.einsum("bhij,bjhp->bihp", w, xc.astype(f32))
        decay_end = jnp.exp(cum_last - cum)         # [b,cs,H]
        Sc = jnp.einsum("bjh,bjhn,bjhp->bhnp", decay_end * dtc,
                        Bc.astype(f32), xc.astype(f32))
        return y_diag, Sc, cum, jnp.exp(cum_last)[:, 0, :]

    y_diag, Sc, cum, gamma = jax.vmap(chunk_fwd)(xs, dts, Bs, Cs)
    y_diag = constrain(y_diag, cdims)
    # Sc [nc,b,H,N,P], gamma [nc,b,H]

    # -- phase 2: tiny sequential state pass ----------------------------
    def step(h, inp):
        Sc_c, g_c = inp
        h_next = h * g_c[..., None, None] + Sc_c
        return h_next, h                            # emit state ENTERING c

    h_final, h_in = lax.scan(step, h0, (Sc, gamma))

    # -- phase 3: per-chunk offset from carried state (parallel) --------
    def chunk_off(Cc, cum_c, h_c):
        return jnp.einsum("bihn,bhnp->bihp", Cc.astype(f32), h_c) \
            * jnp.exp(cum_c)[..., None]

    y_off = jax.vmap(chunk_off)(Cs, cum, h_in)
    y = (y_diag + y_off).astype(x.dtype)
    y = constrain(y, cdims)
    y = y.swapaxes(0, 1).reshape(b, s, H, P)
    return y, h_final


def ssm_apply(cfg: Any, p: PyTree, x: jax.Array, *,
              return_cache: bool = False, kernel_fn: Any = None):
    """Full-sequence mixer.  x [B,S,D] -> [B,S,D] (and decode cache when
    ``return_cache``: final state + conv tail — the prefill path)."""
    b, s, _ = x.shape
    z, xbc_raw, dt_raw, (di, G, N, H) = _split_proj(
        cfg, dense(p["in_proj"], x))
    xbc = _causal_conv(p, xbc_raw)
    xh, Bm, Cm = _heads(cfg, xbc)
    from repro.parallel.sharding import constrain
    xh = constrain(xh, ("batch", None, "ssm_act_heads", None))
    Bm = constrain(Bm, ("batch", None, "ssm_act_heads", None))
    Cm = constrain(Cm, ("batch", None, "ssm_act_heads", None))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])            # [B,S,H]
    dt = constrain(dt, ("batch", None, "ssm_act_heads"))
    A = -jnp.exp(p["A_log"])
    if kernel_fn is not None:
        y, h_final = kernel_fn(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, h_final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D"][:, None].astype(x.dtype)
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm({"g": p["norm_g"]}, y, cfg.norm_eps)
    out = dense(p["out_proj"], y)
    if not return_cache:
        return out, None
    k = cfg.ssm_conv
    tail = xbc_raw[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
        xbc_raw, ((0, 0), (k - 1 - s, 0), (0, 0)))
    return out, {"conv": tail.astype(cfg.dtype), "h": h_final}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def ssm_cache_init(cfg: Any, batch: int, dtype: Any = None) -> PyTree:
    conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch),
                          dtype or cfg.dtype),
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                        cfg.ssm_head_dim), jnp.float32),
    }


def ssm_cache_dims() -> PyTree:
    return {"conv": ("cache_batch", "conv_k", "ssm_conv_ch"),
            "h": ("cache_batch", "ssm_heads", "state", "head")}


def ssm_decode(cfg: Any, p: PyTree, x: jax.Array, cache: PyTree
               ) -> Tuple[jax.Array, PyTree]:
    """One token.  x [B,1,D] -> (y [B,1,D], new cache)."""
    b = x.shape[0]
    z, xbc_raw, dt_raw, (di, G, N, H) = _split_proj(
        cfg, dense(p["in_proj"], x))
    # conv with cached window
    win = jnp.concatenate([cache["conv"].astype(x.dtype), xbc_raw], axis=1)
    k = p["conv_w"].shape[0]
    out = sum(win[:, i, :] * p["conv_w"][i].astype(x.dtype)
              for i in range(k))
    xbc = jax.nn.silu((out + p["conv_b"].astype(x.dtype)
                       ).astype(jnp.float32)).astype(x.dtype)[:, None, :]
    xh, Bm, Cm = _heads(cfg, xbc)                   # [B,1,H,*]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0] * A)                      # [B,H]
    f32 = jnp.float32
    h = cache["h"] * dA[..., None, None]
    h = h + jnp.einsum("bh,bhn,bhp->bhnp", dt[:, 0],
                       Bm[:, 0].astype(f32), xh[:, 0].astype(f32))
    y = jnp.einsum("bhn,bhnp->bhp", Cm[:, 0].astype(f32), h)
    y = y.astype(x.dtype) + xh[:, 0] * p["D"][:, None].astype(x.dtype)
    y = y.reshape(b, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm({"g": p["norm_g"]}, y, cfg.norm_eps)
    new_cache = {"conv": win[:, 1:, :].astype(cache["conv"].dtype), "h": h}
    return dense(p["out_proj"], y), new_cache
