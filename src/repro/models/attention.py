"""Grouped-query attention (GQA) with RoPE — train/prefill/decode paths.

Execution strategies (``cfg.attn_impl`` / ``impl=``):

- ``full``    — one [S, S] score matrix (reference; small shapes only).
- ``chunked`` — pure-JAX flash attention with a **custom VJP**: the
  forward runs online-softmax over KV blocks and saves only
  ``(q, k, v, out, lse)``; the backward recomputes block scores — O(S)
  residual memory instead of the O(S²) block-score stacks that plain
  autodiff-through-scan materializes.  This is the train/prefill
  baseline for the dry-run.
- ``chunked_causal_skip`` — unrolled lower-triangular block schedule:
  causal upper blocks are *omitted from the HLO entirely*, halving
  attention FLOPs (hillclimb step; see EXPERIMENTS.md §Perf).

Sharding: q/k/v are constrained per the logical rules — head dims shard
over ``model`` when divisible (Megatron-style TP attention, row-parallel
all-reduce after ``wo``), and drop to replicated otherwise instead of
letting GSPMD split the contraction (which inserts per-block score
all-reduces — see EXPERIMENTS.md §Perf iteration log).

The Pallas flash kernel (`repro.kernels.flash_attention`) replaces the
inner loop on real TPUs via ``kernels={"flash_attention": ...}``; the
dry-run uses this pure-XLA path (CPU placeholder devices cannot compile
Mosaic kernels).

Decode uses a pre-allocated KV cache ``{k, v: [B, S_max, n_kv, hd]}``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import (PyTree, apply_rope, dense, dense_init, merge, norm,
                     norm_init, rope_cos_sin)

NEG_INF = -1e30


def _constrain(x: jax.Array, dims) -> jax.Array:
    from repro.parallel.sharding import constrain
    return constrain(x, dims)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def attn_init(key: jax.Array, cfg: Any) -> Tuple[PyTree, PyTree]:
    hd = cfg.head_dim
    ks = jax.random.split(key, 6)
    parts = [
        ("wq", dense_init(ks[0], cfg.d_model, cfg.n_heads * hd,
                          dims=("embed", "q_proj"), bias=cfg.qkv_bias,
                          dtype=cfg.param_dtype)),
        ("wk", dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd,
                          dims=("embed", "kv_proj"), bias=cfg.qkv_bias,
                          dtype=cfg.param_dtype)),
        ("wv", dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd,
                          dims=("embed", "kv_proj"), bias=cfg.qkv_bias,
                          dtype=cfg.param_dtype)),
        ("wo", dense_init(ks[3], cfg.n_heads * hd, cfg.d_model,
                          dims=("q_proj", "embed"), bias=False,
                          scale=1.0 / math.sqrt(cfg.n_heads * hd),
                          dtype=cfg.param_dtype)),
    ]
    if cfg.qk_norm:
        parts.append(("qnorm", norm_init("rms", hd, cfg.param_dtype)))
        parts.append(("knorm", norm_init("rms", hd, cfg.param_dtype)))
    return merge(*parts)


# ---------------------------------------------------------------------------
# masking
# ---------------------------------------------------------------------------
def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int], k_valid: Optional[jax.Array] = None
               ) -> jax.Array:
    """[..., Q, K] additive bias in f32."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    if k_valid is not None:
        ok &= k_valid[None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# reference full attention (q [B,Q,Hq,Dk], k/v [B,K,Hkv,D*])
# ---------------------------------------------------------------------------
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """-> [B, Hkv, G, Q, K] grouped scores (f32)."""
    b, qlen, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, qlen, hkv, g, d)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p [B,Hkv,G,Q,K], v [B,K,Hkv,Dv] -> [B,Q,Hq,Dv]."""
    b, hkv, g, qlen, _ = p.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, qlen, hkv * g, v.shape[-1])


def attention_full(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   scale: float, causal: bool, window: Optional[int],
                   q_pos: jax.Array, k_pos: jax.Array,
                   k_valid: Optional[jax.Array] = None) -> jax.Array:
    s = _gqa_scores(q, k) * scale
    s = s + _mask_bias(q_pos, k_pos, causal, window, k_valid)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return _gqa_out(p, v)


# ---------------------------------------------------------------------------
# flash attention (pure JAX, custom VJP).  Grouped layout internally:
# q [B,Hkv,G,S,Dk], k/v [B,Hkv,S,D*].  positions = arange(S).
# ---------------------------------------------------------------------------
def _blocks(x: jax.Array, nb: int, axis: int) -> jax.Array:
    """Split ``axis`` into (nb, block) and move nb to the front."""
    shape = x.shape
    bsz = shape[axis] // nb
    x = x.reshape(shape[:axis] + (nb, bsz) + shape[axis + 1:])
    return jnp.moveaxis(x, axis, 0)


def _cblocks(x, dims):
    """Pin a block-stack sharding via the logical rules."""
    return _constrain(x, dims)


def _tp_size() -> int:
    from repro.parallel.sharding import active_mesh
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def _pick_chunks(s: int, block: int, tp: int) -> Tuple[int, int]:
    """(n_chunks, block) such that n_chunks divides s, is a multiple of
    tp (so the chunk stack shards over ``model``), and the block size is
    closest to the requested one.  Falls back to gcd blocking when no
    tp-aligned divisor exists."""
    best = None
    d = 1
    while d * d <= s:
        if s % d == 0:
            for nq in (d, s // d):
                if nq % tp == 0 and s // nq >= 1:
                    # log-distance: 4 and 16384 are both "far" from 256
                    score = abs(math.log2(s / nq) - math.log2(block))
                    if best is None or score < best[0]:
                        best = (score, nq)
        d += 1
    if best is not None:
        nq = best[1]
        return nq, s // nq
    bq = max(1, math.gcd(s, block))
    return s // bq, bq


def _mode_dims(mode: str):
    """Sharding dims for the q-side 6D stacks / kv-side 5D stacks per
    parallelism mode.

    - ``chunk``: sequence parallelism — chunk dim over model, kv stacks
      replicated (GQA kv is small);
    - ``head``: TP attention — the Hkv dim shards over model (only legal
      when n_kv_heads divides the axis; then *nothing* is replicated and
      attention needs no collectives at all).
    """
    if mode == "head":
        return ((None, "batch", "kv_heads", None, None, None),
                (None, "batch", "kv_heads", None, None, None),
                (None, "batch", "kv_heads", None, None),
                (None, "batch", "kv_heads", None, None))
    return (("attn_chunks", "batch", None, None, None, None),
            (None, "batch", None, None, None, None),
            ("attn_chunks", "batch", None, None, None),
            (None, "batch", None, None, None))


def _flash_fwd_impl(q, k, v, scale, causal, window, bq, bk, mode):
    b, hkv, g, sq, dk = q.shape
    sk, dv = k.shape[2], v.shape[-1]
    nq, nk = sq // bq, sk // bk
    qdims, _, kdims, _ = _mode_dims(mode)
    qb = _cblocks(_blocks(q, nq, 3), qdims)
    # chunk mode: every q-chunk scans the full KV — kv stacks stay
    # replicated over chunks (one all-gather of the small GQA k/v per
    # layer).  head mode: kv sharded by heads, fully local.
    kb = _cblocks(_blocks(k, nk, 2), kdims)
    vb = _cblocks(_blocks(v, nk, 2), kdims)

    def q_chunk(qi, qblk):
        q_pos = qi * bq + jnp.arange(bq)
        acc0 = jnp.zeros((b, hkv, g, bq, dv), jnp.float32)
        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)

        def kv_step(carry, args2):
            kj, kblk, vblk = args2
            acc, m, l = carry
            k_pos = kj * bk + jnp.arange(bk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _mask_bias(q_pos, k_pos, causal, window)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vblk.dtype),
                            vblk)
            acc = acc * alpha[..., None].astype(acc.dtype) + pv
            return (acc, m_new, l), None

        (acc, m, l), _ = lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nk), kb, vb))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(v.dtype)
        lse = m + jnp.log(l_safe)
        return out, lse                           # [B,Hkv,G,bq,dv], [..bq]

    # vmap (not lax.map): the chunk dim stays a *batched* dim, so GSPMD
    # shards the attention compute over it (a sequential loop cannot be
    # sharded)
    outs, lses = jax.vmap(q_chunk)(jnp.arange(nq), qb)
    outs = _cblocks(outs, qdims)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, dv)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hkv, g, sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, scale, causal, window, bq, bk, mode):
    out, _ = _flash_fwd_impl(q, k, v, scale, causal, window, bq, bk, mode)
    return out


def _flash_fwd(q, k, v, scale, causal, window, bq, bk, mode):
    out, lse = _flash_fwd_impl(q, k, v, scale, causal, window, bq, bk,
                               mode)
    return out, (q, k, v, out, lse)


def _flash_bwd(scale, causal, window, bq, bk, mode, res, dout):
    """Single-pass flash backward, vmapped over q chunks: each chunk
    computes its dq locally AND emits per-(q,kv)-block dk/dv
    contributions; the sum over the (sharded) chunk dim is the dk/dv
    reduction GSPMD lowers to one reduce over the model axis.

    vs. the classic two-pass form this (i) never replicates the q-side
    stacks across sequence shards (§Perf iteration 3 — the 2-pass dkv
    sweep all-gathered q/do/out per layer), and (ii) computes p/ds once
    per block pair: 5 matmuls instead of 7."""
    q, k, v, out, lse = res
    b, hkv, g, sq, dk = q.shape
    sk, dv = k.shape[2], v.shape[-1]
    nq, nk = sq // bq, sk // bk
    cdims6, rdims6, cdims5, rdims5 = _mode_dims(mode)
    qb = _cblocks(_blocks(q, nq, 3), cdims6)
    dob = _cblocks(_blocks(dout, nq, 3), cdims6)
    outb = _cblocks(_blocks(out, nq, 3), cdims6)
    lseb = _cblocks(_blocks(lse, nq, 3), cdims5)
    kb = _cblocks(_blocks(k, nk, 2), rdims5)
    vb = _cblocks(_blocks(v, nk, 2), rdims5)
    f32 = jnp.float32

    def chunk_bwd(qi, qblk, doblk, oblk, lblk):
        q_pos = qi * bq + jnp.arange(bq)
        Di = jnp.sum(doblk.astype(f32) * oblk.astype(f32), axis=-1)

        def kv_step(dq_i, args2):
            kj, kblk, vblk = args2
            k_pos = kj * bk + jnp.arange(bk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=f32) * scale
            s = s + _mask_bias(q_pos, k_pos, causal, window)
            p = jnp.exp(s - lblk[..., None])     # [B,Hkv,G,bq,bk]
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doblk.astype(f32),
                            vblk.astype(f32))
            ds = p * (dp - Di[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhgqk,bhkd->bhgqd",
                                     ds.astype(f32), kblk.astype(f32))
            dv_blk = jnp.einsum("bhgqk,bhgqd->bhkd", p.astype(f32),
                                doblk.astype(f32))
            dk_blk = jnp.einsum("bhgqk,bhgqd->bhkd", ds.astype(f32),
                                qblk.astype(f32))
            return dq_i, (dk_blk, dv_blk)

        dq0 = jnp.zeros((b, hkv, g, bq, dk), f32)
        dq_i, (dk_parts, dv_parts) = lax.scan(
            kv_step, dq0, (jnp.arange(nk), kb, vb))
        return dq_i, dk_parts, dv_parts         # parts: [nk,B,Hkv,bk,d]

    dqs, dkp, dvp = jax.vmap(chunk_bwd)(jnp.arange(nq), qb, dob, outb,
                                        lseb)
    dqs = _cblocks(dqs, cdims6)
    # sum per-chunk contributions; the chunk dim is sharded in chunk
    # mode, so this is a cross-shard reduce of the SMALL GQA dk/dv
    dks = dkp.sum(axis=0)
    dvs = dvp.sum(axis=0)
    dks = _cblocks(dks, rdims5)
    dvs = _cblocks(dvs, rdims5)
    dq = jnp.moveaxis(dqs, 0, 3).reshape(b, hkv, g, sq, dk).astype(q.dtype)
    dk_out = jnp.moveaxis(dks, 0, 2).reshape(b, hkv, sk, dk).astype(k.dtype)
    dv_out = jnp.moveaxis(dvs, 0, 2).reshape(b, hkv, sk, dv).astype(v.dtype)
    return dq, dk_out, dv_out


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      scale: float, causal: bool, window: Optional[int],
                      q_block: int, k_block: int,
                      causal_skip: bool = False) -> jax.Array:
    """Model-layout wrapper.  q [B,S,Hq,Dk], k/v [B,S,Hkv,D*] (positions
    are arange(S)) -> [B,S,Hq,Dv]."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    tp = _tp_size()
    # parallelism mode: TP by kv heads when they divide the model axis
    # (collective-free), sequence/chunk parallelism otherwise
    mode = "head" if (tp > 1 and hkv % tp == 0) else "chunk"
    if mode == "chunk" and tp > 1:
        # the chunk count must be a multiple of tp or the chunk sharding
        # silently drops (e.g. VLM S=4096+576 — §Perf iteration 1)
        _, bq = _pick_chunks(s, q_block, tp)
        bk = max(1, math.gcd(s, k_block))
    else:
        bq = max(1, math.gcd(s, q_block))
        bk = max(1, math.gcd(s, k_block))
    qg = jnp.moveaxis(q.reshape(b, s, hkv, g, d), 1, 3)  # [B,Hkv,G,S,D]
    kg = jnp.moveaxis(k, 1, 2)                           # [B,Hkv,S,D]
    vg = jnp.moveaxis(v, 1, 2)
    if mode == "head":
        qg = _constrain(qg, ("batch", "kv_heads", None, None, None))
        kg = _constrain(kg, ("batch", "kv_heads", None, None))
        vg = _constrain(vg, ("batch", "kv_heads", None, None))
    if causal_skip and causal and window is None:
        out = _flash_causal_skip(qg, kg, vg, scale, bq, bk)
    else:
        out = _flash(qg, kg, vg, scale, causal, window, bq, bk, mode)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, hq, v.shape[-1])


def _flash_causal_skip(q, k, v, scale, bq, bk):
    """Unrolled triangular schedule: upper blocks never emitted.  Memory
    behaviour of autodiff here is the plain-scan one per *diagonal row*,
    acceptable because block count is triangular; used as a §Perf
    iteration, not the default."""
    b, hkv, g, sq, dk = q.shape
    sk, dv = k.shape[2], v.shape[-1]
    nq, nk = sq // bq, sk // bk
    outs = []
    for qi in range(nq):
        qblk = lax.dynamic_slice_in_dim(q, qi * bq, bq, 3)
        q_pos = qi * bq + jnp.arange(bq)
        acc = jnp.zeros((b, hkv, g, bq, dv), jnp.float32)
        m = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, g, bq), jnp.float32)
        for kj in range(min(qi + 1, nk)):
            kblk = lax.dynamic_slice_in_dim(k, kj * bk, bk, 2)
            vblk = lax.dynamic_slice_in_dim(v, kj * bk, bk, 2)
            k_pos = kj * bk + jnp.arange(bk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _mask_bias(q_pos, k_pos, True, None)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vblk.dtype),
                            vblk)
            acc = acc * alpha[..., None].astype(acc.dtype) + pv
            m = m_new
        out_i = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(v.dtype)
        outs.append(out_i)
    return jnp.concatenate(outs, axis=3)


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def _project_qkv(cfg: Any, p: PyTree, x: jax.Array, positions: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = norm("rms", p["qnorm"], q, cfg.norm_eps)
        k = norm("rms", p["knorm"], k, cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    tp = _tp_size()
    if s > 1 and not (tp > 1 and cfg.n_kv_heads % tp == 0):
        # chunk (sequence-parallel) mode: pin projections seq-sharded.
        # head mode leaves them alone — the column-parallel weight
        # sharding already produces head-sharded q/k/v locally.
        q = _constrain(q, ("batch", "seq", None, None))
        k = _constrain(k, ("batch", "seq", None, None))
        v = _constrain(v, ("batch", "seq", None, None))
    return q, k, v


def attn_apply(cfg: Any, p: PyTree, x: jax.Array, *,
               positions: jax.Array,
               impl: str = "chunked",
               kernel_fn: Any = None) -> jax.Array:
    """Full-sequence (train/prefill) attention.  x [B,S,D]."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if kernel_fn is not None:
        out = kernel_fn(q, k, v, causal=cfg.causal, scale=scale)
    elif impl == "full" or s <= cfg.q_block:
        out = attention_full(q, k, v, scale=scale, causal=cfg.causal,
                             window=cfg.sliding_window, q_pos=positions,
                             k_pos=positions)
    else:
        out = attention_chunked(
            q, k, v, scale=scale, causal=cfg.causal,
            window=cfg.sliding_window,
            q_block=cfg.q_block, k_block=cfg.q_block,
            causal_skip=(impl == "chunked_causal_skip"))
    tp = _tp_size()
    if tp > 1 and cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0:
        # head-TP: out stays head-sharded into the row-parallel wo
        # ("kv_heads" rule resolves to the model axis w/ divisibility)
        out = _constrain(out, ("batch", None, "kv_heads", None))
    elif s > 1:
        out = _constrain(out, ("batch", "seq", None, None))
    return dense(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.head_dim))


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------
def seq_sharded_decode(smax: int) -> bool:
    """True when the decode cells run with the KV cache sharded along
    the sequence dim over ``model`` (context-parallel decode — set by
    launch.steps.decode_rules for archs whose kv-head count cannot shard
    the model axis, and always for MLA's head-less latent cache)."""
    from repro.parallel.sharding import active_mesh, active_rules
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    if mesh.shape["model"] <= 1 or smax % mesh.shape["model"]:
        return False
    return "model" in active_rules().get("cache_seq", ())


def _dp_prefix(mesh, b: int):
    axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.shape and b % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
        else:
            break
    return tuple(axes) if axes else None


def _local_row_update(buf: jax.Array, row: jax.Array, off: jax.Array,
                      in_range: jax.Array) -> jax.Array:
    """Write ``row`` at local offset ``off`` iff ``in_range`` — O(1 row)
    (a full-buffer select would rewrite the whole cache every token)."""
    off_c = jnp.clip(off, 0, buf.shape[1] - row.shape[1])
    start = (0, off_c) + (0,) * (buf.ndim - 2)
    cur = lax.dynamic_slice(buf, start, row.shape)
    row = jnp.where(in_range, row.astype(buf.dtype), cur)
    return lax.dynamic_update_slice(buf, row, start)


def _flash_decode_combine(acc, m, l, axis: str):
    """Flash-decoding softmax combine across sequence shards."""
    m_g = lax.pmax(m, axis)
    corr = jnp.exp(m - m_g)
    l_g = lax.psum(l * corr, axis)
    acc_g = lax.psum(acc * corr[..., None], axis)
    return acc_g / jnp.maximum(l_g, 1e-30)[..., None]


def attn_decode_sharded(cfg: Any, q: jax.Array, k_new: jax.Array,
                        v_new: jax.Array, cache: PyTree,
                        length: jax.Array) -> Tuple[jax.Array, PyTree]:
    """Context-parallel decode: the KV cache stays sharded along seq
    over ``model``; each shard updates its local rows and computes a
    partial softmax, combined with pmax/psum (flash-decoding)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.sharding import active_mesh
    mesh = active_mesh()
    b = q.shape[0]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    bspec = _dp_prefix(mesh, b)
    cspec = P(bspec, "model", None, None)
    qspec = P(bspec, None, None, None)

    def body(q_, kn, vn, ck, cv, ln):
        rank = lax.axis_index("model")
        s_loc = ck.shape[1]
        start = rank * s_loc
        off = ln - start
        in_range = (off >= 0) & (off < s_loc)
        ck = _local_row_update(ck, kn, off, in_range)
        cv = _local_row_update(cv, vn, off, in_range)
        s = _gqa_scores(q_, ck.astype(q_.dtype)) * scale  # [B,Hkv,G,1,Sl]
        pos = start + jnp.arange(s_loc)
        s = jnp.where((pos <= ln)[None, None, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        acc = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(cv.dtype),
                         cv.astype(q_.dtype))
        out = _flash_decode_combine(acc, m, l, "model")
        return out.astype(q_.dtype), ck, cv

    out, ck, cv = shard_map(
        body, mesh=mesh,
        in_specs=(qspec, qspec, qspec, cspec, cspec, P()),
        out_specs=(P(bspec, None, None, None, None), cspec, cspec),
        check_rep=False)(q, k_new, v_new, cache["k"], cache["v"], length)
    # out [B,Hkv,G,1,dv] -> [B,1,Hq,dv]
    b_, hkv, g, _, dv = out.shape
    y = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b_, 1, hkv * g, dv)
    return y, {"k": ck, "v": cv}


def attn_cache_init(cfg: Any, batch: int, max_seq: int,
                    dtype: Any = None) -> PyTree:
    dtype = dtype or cfg.dtype
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_cache_dims() -> PyTree:
    return {"k": ("cache_batch", "cache_seq", "kv_heads", "head"),
            "v": ("cache_batch", "cache_seq", "kv_heads", "head")}


def attn_decode(cfg: Any, p: PyTree, x: jax.Array, cache: PyTree,
                length: jax.Array) -> Tuple[jax.Array, PyTree]:
    """One decode step.  x [B,1,D]; cache k/v [B,Smax,Hkv,hd]; length []
    (tokens already in cache).  Returns (y [B,1,D], new_cache)."""
    b = x.shape[0]
    positions = jnp.full((1,), length, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    if seq_sharded_decode(cache["k"].shape[1]):
        out, new_cache = attn_decode_sharded(cfg, q, k_new, v_new, cache,
                                             length)
        y = dense(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.head_dim))
        return y, new_cache
    k = lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                 (0, length, 0, 0))
    v = lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                 (0, length, 0, 0))
    smax = k.shape[1]
    k_pos = jnp.arange(smax, dtype=jnp.int32)
    k_valid = k_pos <= length
    scale = 1.0 / math.sqrt(cfg.head_dim)
    out = attention_full(q, k.astype(x.dtype), v.astype(x.dtype),
                         scale=scale, causal=False, window=cfg.sliding_window,
                         q_pos=positions, k_pos=k_pos, k_valid=k_valid)
    y = dense(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.head_dim))
    return y, {"k": k, "v": v}
