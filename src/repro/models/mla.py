"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are projected through low-rank latents:

  q:   x -> w_dq [d, q_lora] -> rmsnorm -> w_uq [q_lora, H*(nope+rope)]
  kv:  x -> w_dkv [d, kv_lora + rope]   (k_rope is *shared* across heads)
       c_kv -> rmsnorm -> w_ukv [kv_lora, H*(nope+v)]

RoPE is applied only to the rope sub-dimensions.  The decode path uses
the **absorbed** formulation: ``w_uk`` is folded into the query and
``w_uv`` into the output so attention runs directly against the cached
latent ``c_kv`` — the cache is [B, S, kv_lora + rope] instead of
[B, S, H, 2·hd] (the paper-V2 memory saving, 576 vs 32768 per token for
V3's 128 heads).
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import (PyTree, dense, dense_init, merge, norm, norm_init,
                     rope_cos_sin)
from .attention import NEG_INF


def _rope_interleaved(x: jax.Array, cos: jax.Array, sin: jax.Array
                      ) -> jax.Array:
    """x [..., S, H, D] (D even), cos/sin [S, D/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def mla_init(key: jax.Array, cfg: Any) -> Tuple[PyTree, PyTree]:
    H = cfg.n_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    parts = [
        ("w_dq", dense_init(ks[0], cfg.d_model, cfg.q_lora_rank,
                            dims=("embed", "q_lora"),
                            dtype=cfg.param_dtype)),
        ("qnorm", norm_init("rms", cfg.q_lora_rank, cfg.param_dtype)),
        ("w_uq", dense_init(ks[1], cfg.q_lora_rank, H * qk,
                            dims=("q_lora", "q_proj"),
                            dtype=cfg.param_dtype)),
        ("w_dkv", dense_init(ks[2], cfg.d_model,
                             cfg.kv_lora_rank + cfg.qk_rope_head_dim,
                             dims=("embed", "kv_lora"),
                             dtype=cfg.param_dtype)),
        ("kvnorm", norm_init("rms", cfg.kv_lora_rank, cfg.param_dtype)),
        ("w_uk", dense_init(ks[3], cfg.kv_lora_rank,
                            H * cfg.qk_nope_head_dim,
                            dims=("kv_lora", "q_proj"),
                            dtype=cfg.param_dtype)),
        ("w_uv", dense_init(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim,
                            dims=("kv_lora", "q_proj"),
                            dtype=cfg.param_dtype)),
        ("wo", dense_init(ks[5], H * cfg.v_head_dim, cfg.d_model,
                          dims=("q_proj", "embed"),
                          scale=1.0 / math.sqrt(H * cfg.v_head_dim),
                          dtype=cfg.param_dtype)),
    ]
    return merge(*parts)


def _queries(cfg: Any, p: PyTree, x: jax.Array, positions: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """-> (q_nope [B,S,H,nope], q_rope [B,S,H,rope])."""
    b, s, _ = x.shape
    H = cfg.n_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    cq = norm("rms", p["qnorm"], dense(p["w_dq"], x), cfg.norm_eps)
    q = dense(p["w_uq"], cq).reshape(b, s, H, qk)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = q[..., cfg.qk_nope_head_dim:]
    cos, sin = rope_cos_sin(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
    q_rope = _rope_interleaved(q_rope, cos, sin)
    return q_nope, q_rope


def _latents(cfg: Any, p: PyTree, x: jax.Array, positions: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """-> (c_kv [B,S,kv_lora] normed, k_rope [B,S,rope] roped)."""
    ckv_full = dense(p["w_dkv"], x)
    c_kv = norm("rms", p["kvnorm"], ckv_full[..., : cfg.kv_lora_rank],
                cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:]
    cos, sin = rope_cos_sin(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
    k_rope = _rope_interleaved(k_rope[..., None, :], cos, sin)[..., 0, :]
    return c_kv, k_rope


# ---------------------------------------------------------------------------
# full-sequence (train / prefill): up-project then standard attention
# ---------------------------------------------------------------------------
def mla_apply(cfg: Any, p: PyTree, x: jax.Array, *,
              positions: jax.Array, impl: str = "chunked") -> jax.Array:
    b, s, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _queries(cfg, p, x, positions)
    c_kv, k_rope = _latents(cfg, p, x, positions)
    k_nope = dense(p["w_uk"], c_kv).reshape(b, s, H, cfg.qk_nope_head_dim)
    v = dense(p["w_uv"], c_kv).reshape(b, s, H, cfg.v_head_dim)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)

    # flash attention over KV blocks (scores = nope + shared rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[..., None, :],
                                  (b, s, H, cfg.qk_rope_head_dim))],
        axis=-1)
    from .attention import (attention_chunked, attention_full, _constrain,
                            _tp_size)
    if _tp_size() > 1 and H % _tp_size() == 0:
        # head-TP (the MLA case: 128 heads): q/k/v head-sharded straight
        # out of the column-parallel up-projections
        q = _constrain(q, ("batch", None, "kv_heads", None))
        k = _constrain(k, ("batch", None, "kv_heads", None))
        v = _constrain(v, ("batch", None, "kv_heads", None))
    if impl == "full" or s <= cfg.q_block:
        out = attention_full(q, k, v, scale=scale, causal=cfg.causal,
                             window=None, q_pos=positions, k_pos=positions)
    else:
        out = attention_chunked(
            q, k, v, scale=scale, causal=cfg.causal, window=None,
            q_block=cfg.q_block, k_block=cfg.q_block,
            causal_skip=(impl == "chunked_causal_skip"))
    if _tp_size() > 1 and H % _tp_size() == 0:
        out = _constrain(out, ("batch", None, "kv_heads", None))
    elif s > 1:
        out = _constrain(out, ("batch", "seq", None, None))
    return dense(p["wo"], out.reshape(b, s, H * cfg.v_head_dim))


# ---------------------------------------------------------------------------
# decode: absorbed matmuls against the latent cache
# ---------------------------------------------------------------------------
def mla_cache_init(cfg: Any, batch: int, max_seq: int,
                   dtype: Any = None) -> PyTree:
    dtype = dtype or cfg.dtype
    return {"ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim),
                               dtype)}


def mla_cache_dims() -> PyTree:
    return {"ckv": ("cache_batch", "cache_seq", "kv_lora"),
            "krope": ("cache_batch", "cache_seq", "head")}


def mla_decode(cfg: Any, p: PyTree, x: jax.Array, cache: PyTree,
               length: jax.Array) -> Tuple[jax.Array, PyTree]:
    """One decode step with the absorbed formulation.

    scores = q_nope @ w_uk^T @ ckv  +  q_rope @ k_rope
    out    = (attn @ ckv) @ w_uv
    """
    b = x.shape[0]
    H = cfg.n_heads
    positions = jnp.full((1,), length, jnp.int32)
    q_nope, q_rope = _queries(cfg, p, x, positions)   # [B,1,H,*]
    c_new, kr_new = _latents(cfg, p, x, positions)    # [B,1,kv_lora/rope]
    from .attention import seq_sharded_decode
    if seq_sharded_decode(cache["ckv"].shape[1]):
        return _mla_decode_sharded(cfg, p, x, q_nope, q_rope, c_new,
                                   kr_new, cache, length)
    ckv = lax.dynamic_update_slice(
        cache["ckv"], c_new.astype(cache["ckv"].dtype), (0, length, 0))
    krope = lax.dynamic_update_slice(
        cache["krope"], kr_new.astype(cache["krope"].dtype), (0, length, 0))
    smax = ckv.shape[1]

    # absorb w_uk into the query: q_lat [B,1,H,kv_lora]
    wuk = p["w_uk"]["w"].reshape(cfg.kv_lora_rank, H, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, wuk.astype(x.dtype))
    s_nope = jnp.einsum("bqhl,bkl->bhqk", q_lat, ckv.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, krope.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    s = (s_nope + s_rope) * scale
    k_valid = jnp.arange(smax) <= length
    s = jnp.where(k_valid[None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqk,bkl->bqhl", pattn, ckv.astype(x.dtype))
    wuv = p["w_uv"]["w"].reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
    out = jnp.einsum("bqhl,lhd->bqhd", o_lat, wuv.astype(x.dtype))
    y = dense(p["wo"], out.reshape(b, 1, H * cfg.v_head_dim))
    return y, {"ckv": ckv, "krope": krope}


def _mla_decode_sharded(cfg: Any, p: PyTree, x: jax.Array,
                        q_nope: jax.Array, q_rope: jax.Array,
                        c_new: jax.Array, kr_new: jax.Array,
                        cache: PyTree, length: jax.Array
                        ) -> Tuple[jax.Array, PyTree]:
    """Context-parallel absorbed decode: the latent cache stays sharded
    along seq over ``model``; partial softmax combined flash-decoding
    style (see attention.attn_decode_sharded)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.sharding import active_mesh
    from .attention import (_dp_prefix, _flash_decode_combine,
                            _local_row_update)
    mesh = active_mesh()
    b = x.shape[0]
    H = cfg.n_heads
    wuk = p["w_uk"]["w"].reshape(cfg.kv_lora_rank, H, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, wuk.astype(x.dtype))
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    bspec = _dp_prefix(mesh, b)
    c3 = P(bspec, "model", None)

    def body(ql, qr, cn, kn, ckv, krope, ln):
        rank = lax.axis_index("model")
        s_loc = ckv.shape[1]
        start = rank * s_loc
        off = ln - start
        in_range = (off >= 0) & (off < s_loc)
        ckv = _local_row_update(ckv, cn, off, in_range)
        krope = _local_row_update(krope, kn, off, in_range)
        s_nope = jnp.einsum("bqhl,bkl->bhqk", ql, ckv.astype(ql.dtype),
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhd,bkd->bhqk", qr, krope.astype(qr.dtype),
                            preferred_element_type=jnp.float32)
        s = (s_nope + s_rope) * scale               # [B,H,1,Sl]
        pos = start + jnp.arange(s_loc)
        s = jnp.where((pos <= ln)[None, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)
        pr = jnp.exp(s - m[..., None])
        l = pr.sum(axis=-1)
        acc = jnp.einsum("bhqk,bkl->bhql", pr.astype(ckv.dtype),
                         ckv).astype(jnp.float32)
        o = _flash_decode_combine(acc, m, l, "model")
        return o.astype(ql.dtype), ckv, krope

    o_lat, ckv, krope = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, None, None), P(bspec, None, None), c3, c3, P()),
        out_specs=(P(bspec, None, None, None), c3, c3),
        check_rep=False)(q_lat, q_rope, c_new, kr_new,
                         cache["ckv"], cache["krope"], length)
    o_lat = jnp.moveaxis(o_lat, 1, 2)               # [B,1,H,kv_lora]
    wuv = p["w_uv"]["w"].reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
    out = jnp.einsum("bqhl,lhd->bqhd", o_lat, wuv.astype(x.dtype))
    y = dense(p["wo"], out.reshape(b, 1, H * cfg.v_head_dim))
    return y, {"ckv": ckv, "krope": krope}
