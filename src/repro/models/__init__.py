from .model import (abstract_init, apply_model, decode_step, init_cache,
                    init_model, loss_fn, prefill)
from . import attention, common, mla, moe, model, ssm

__all__ = ["apply_model", "decode_step", "init_cache", "init_model",
           "loss_fn", "prefill", "attention", "common", "mla", "moe",
           "model", "ssm"]
