"""Mixture-of-Experts with expert-parallel dispatch over LCX.

Three backends (``cfg.moe_backend``):

- ``dense`` — loop-over-experts masked reference (exact, O(E·T·d·f)
  compute; smoke tests / correctness oracle only).
- ``sort``  — single-device sort-based capacity dispatch (argsort by
  expert id, position-in-expert from group offsets, capacity drop),
  the local building block of the EP path.
- ``lcx``   — expert parallelism: tokens are sharded over the ``model``
  mesh axis (sequence-parallel when S divides, token-sliced otherwise),
  dispatched to experts with an **LCX all-to-all** (`repro.core`
  collectives — the paper's fine-grained async a2a is exactly the MoE
  dispatch pattern), expert FFN computed on the local expert shard, and
  combined with a second a2a.  Runs inside ``shard_map`` over the active
  mesh (see `repro.parallel.sharding.active_mesh`).

Routers: ``softmax`` (standard top-k) and ``sigmoid`` (DeepSeek-V3 style
with top-k normalization).  Aux loss is the Switch load-balancing loss.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import PyTree, dense_init, merge, swiglu


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _expert_stack(key: jax.Array, E: int, d_in: int, d_out: int,
                  dims: Tuple[str, ...], dtype: Any) -> Tuple[PyTree, PyTree]:
    scale = 1.0 / math.sqrt(d_in)
    w = (jax.random.normal(key, (E, d_in, d_out), jnp.float32)
         * scale).astype(dtype)
    return {"w": w}, {"w": ("experts",) + dims}


def moe_init(key: jax.Array, cfg: Any) -> Tuple[PyTree, PyTree]:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    parts = [
        ("router", dense_init(ks[0], d, E, dims=("embed", "router"),
                              dtype=jnp.float32)),
        ("w_gate", _expert_stack(ks[1], E, d, f, ("embed", "moe_mlp"),
                                 cfg.param_dtype)),
        ("w_up", _expert_stack(ks[2], E, d, f, ("embed", "moe_mlp"),
                               cfg.param_dtype)),
        ("w_down", _expert_stack(ks[3], E, f, d, ("moe_mlp", "embed"),
                                 cfg.param_dtype)),
    ]
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.moe_d_ff
        parts.append(("shared_gate", dense_init(
            ks[4], d, fs, dims=("embed", "mlp"), dtype=cfg.param_dtype)))
        parts.append(("shared_up", dense_init(
            jax.random.fold_in(ks[4], 1), d, fs, dims=("embed", "mlp"),
            dtype=cfg.param_dtype)))
        parts.append(("shared_down", dense_init(
            ks[5], fs, d, dims=("mlp", "embed"), dtype=cfg.param_dtype)))
    return merge(*parts)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------
def route(cfg: Any, router_p: PyTree, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [T, d] -> (ids [T, k], weights [T, k] f32, aux_loss scalar)."""
    logits = (x.astype(jnp.float32)
              @ router_p["w"].astype(jnp.float32))          # [T, E]
    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(scores, cfg.n_experts_per_tok)
    if cfg.router_norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance aux: E * sum_e f_e * P_e
    E = cfg.n_experts
    probs = (scores if cfg.router_type != "sigmoid"
             else jax.nn.softmax(logits, axis=-1))
    f = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(ids.size, 1)
    aux = E * jnp.sum(f * probs.mean(0))
    return ids, w, aux


# ---------------------------------------------------------------------------
# expert FFN on a capacity buffer  xb [E_loc, Cb, d]
# ---------------------------------------------------------------------------
def _expert_ffn(p: PyTree, xb: jax.Array, e_start: int, e_count: int
                ) -> jax.Array:
    wg = lax.dynamic_slice_in_dim(p["w_gate"]["w"], e_start, e_count, 0)
    wu = lax.dynamic_slice_in_dim(p["w_up"]["w"], e_start, e_count, 0)
    wd = lax.dynamic_slice_in_dim(p["w_down"]["w"], e_start, e_count, 0)
    g = jnp.einsum("ecd,edf->ecf", xb, wg.astype(xb.dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, wu.astype(xb.dtype))
    return jnp.einsum("ecf,efd->ecd", swiglu(g, u), wd.astype(xb.dtype))


# ---------------------------------------------------------------------------
# sort-based capacity dispatch (local)
# ---------------------------------------------------------------------------
def capacity(cfg: Any, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.n_experts_per_tok / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)        # multiple of 8 for TPU alignment


def dispatch(x_flat: jax.Array, ids: jax.Array, w: jax.Array, E: int,
             C: int) -> Tuple[jax.Array, PyTree]:
    """x_flat [T, d]; ids/w [T, k] -> (buf [E, C, d], combine info).

    Stable-sort by expert id; position within expert from group offsets;
    tokens beyond capacity are dropped (scatter mode='drop')."""
    T, k = ids.shape
    d = x_flat.shape[-1]
    flat_ids = ids.reshape(-1)                       # [T*k]
    order = jnp.argsort(flat_ids, stable=True)
    ids_s = flat_ids[order]
    tok_s = order // k
    sizes = jnp.bincount(flat_ids, length=E)
    starts = jnp.cumsum(sizes) - sizes
    pos = jnp.arange(T * k) - starts[ids_s]
    keep = pos < C
    slot = jnp.where(keep, ids_s * C + pos, E * C)   # E*C = drop bucket
    buf = jnp.zeros((E * C, d), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[tok_s], mode="drop")
    info = {"slot": slot, "tok": tok_s,
            "w": w.reshape(-1)[order].astype(jnp.float32), "T": T}
    return buf.reshape(E, C, d), info


def combine(yb: jax.Array, info: PyTree, d: int) -> jax.Array:
    """yb [E, C, d] -> y [T, d] weighted scatter-add."""
    yb_flat = yb.reshape(-1, d)
    gathered = jnp.take(yb_flat, jnp.minimum(info["slot"],
                                             yb_flat.shape[0] - 1), axis=0)
    gathered = jnp.where((info["slot"] < yb_flat.shape[0])[:, None],
                         gathered, 0)
    y = jnp.zeros((info["T"], d), yb.dtype)
    return y.at[info["tok"]].add(gathered
                                 * info["w"][:, None].astype(yb.dtype))


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------
def _moe_dense(cfg: Any, p: PyTree, x_flat: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Masked loop-over-experts reference."""
    ids, w, aux = route(cfg, p["router"], x_flat)
    y = jnp.zeros_like(x_flat)
    for e in range(cfg.n_experts):
        mask = (ids == e).astype(jnp.float32) * w          # [T, k]
        gate = mask.sum(-1).astype(x_flat.dtype)           # [T]
        he = _expert_ffn(p, x_flat[None], e, 1)[0]
        y = y + he * gate[:, None]
    return y, aux


def _moe_sort_local(cfg: Any, p: PyTree, x_flat: jax.Array,
                    stream_chunks: int = 0) -> Tuple[jax.Array, jax.Array]:
    ids, w, aux = route(cfg, p["router"], x_flat)
    C = capacity(cfg, x_flat.shape[0])
    buf, info = dispatch(x_flat, ids, w, cfg.n_experts, C)
    if stream_chunks > 1 and cfg.n_experts % stream_chunks == 0:
        # decode path: stream FSDP-sharded expert weights in chunks (a
        # scan with dynamic slices bounds the gathered weight slab to
        # E/stream_chunks experts at a time instead of all E)
        E, ck = cfg.n_experts, cfg.n_experts // stream_chunks
        bufc = buf.reshape(stream_chunks, ck, C, -1)

        def body(_, args):
            i, xb = args
            return None, _expert_ffn(p, xb, i * ck, ck)

        _, ybs = lax.scan(body, None,
                          (jnp.arange(stream_chunks) , bufc))
        yb = ybs.reshape(E, C, -1)
    else:
        yb = _expert_ffn(p, buf, 0, cfg.n_experts)
    return combine(yb, info, x_flat.shape[-1]), aux


def _moe_ep_shard(cfg: Any, p: PyTree, x_flat: jax.Array, ep_axis: str,
                  a2a_backend: str) -> Tuple[jax.Array, jax.Array]:
    """Body under shard_map: x_flat [T_loc, d] tokens of THIS rank;
    expert weights in ``p`` are the full stacks (sliced locally)."""
    import repro.core as lcx
    from repro.compat import axis_size
    ep = axis_size(ep_axis)
    rank = lax.axis_index(ep_axis)
    E = cfg.n_experts
    E_loc = E // ep
    d = x_flat.shape[-1]
    ids, w, aux = route(cfg, p["router"], x_flat)
    C = capacity(cfg, x_flat.shape[0])
    buf, info = dispatch(x_flat, ids, w, E, C)             # [E, C, d]

    # Private runtime + isolated device per a2a region: the MoE layer's
    # traffic never touches (or requires) the global default runtime.
    rt = lcx.Runtime(name="moe-ep")
    dev = rt.device(axis=ep_axis)
    a2a = lcx.all_to_all_x(buf.reshape(E * C, d)).device(dev) \
        .backend(a2a_backend)()
    # rows grouped by source rank: [ep, E_loc, C, d] -> [E_loc, ep*C, d]
    xb = a2a.reshape(ep, E_loc, C, d).transpose(1, 0, 2, 3) \
        .reshape(E_loc, ep * C, d)
    # expert weights arrive pre-sharded over the EP axis ([E_loc, ...])
    yb = _expert_ffn(p, xb, 0, E_loc)
    back = yb.reshape(E_loc, ep, C, d).transpose(1, 0, 2, 3) \
        .reshape(E * C, d)
    y_all = lcx.all_to_all_x(back).device(dev).backend(a2a_backend)()
    y = combine(y_all.reshape(E, C, d), info, d)
    return y, aux


def _resident_ok(cfg: Any, mesh: Any) -> bool:
    """Resident-expert decode needs (i) the experts rule to actually
    shard over the joint axes (set by launch.steps.decode_rules), (ii)
    the resident slab to fit the HBM budget."""
    from repro.parallel.sharding import active_rules
    axes = resident_plan(cfg, mesh)
    return axes is not None \
        and tuple(active_rules().get("experts", ())) == axes


def moe_apply(cfg: Any, p: PyTree, x: jax.Array) -> Tuple[jax.Array,
                                                          jax.Array]:
    """x [B, S, d] -> (y [B, S, d], aux loss scalar)."""
    from repro.parallel.sharding import active_mesh, dp_axes, ep_axis_name
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    backend = cfg.moe_backend
    mesh = active_mesh()
    if s == 1 and backend == "lcx" and mesh is not None \
            and _resident_ok(cfg, mesh):
        # decode with RESIDENT experts (sharded over data x model): no
        # weight streaming at all — §Perf iteration 6
        y, aux = _moe_resident_decode(cfg, p, x_flat, mesh)
    elif s == 1 and backend == "lcx" and mesh is not None:
        # decode fallback: weight-streamed local compute with chunked
        # expert gathers (bounds the FSDP slab)
        y, aux = _moe_sort_local(cfg, p, x_flat,
                                 stream_chunks=min(16, cfg.n_experts))
    elif backend == "lcx" and mesh is not None \
            and ep_axis_name() in mesh.axis_names \
            and mesh.shape[ep_axis_name()] > 1 \
            and cfg.n_experts % mesh.shape[ep_axis_name()] == 0:
        y, aux = _moe_ep(cfg, p, x, mesh)
    elif backend == "dense":
        y, aux = _moe_dense(cfg, p, x_flat)
    else:
        y, aux = _moe_sort_local(cfg, p, x_flat)
    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        from .common import dense
        g = dense(p["shared_gate"], x)
        u = dense(p["shared_up"], x)
        y = y + dense(p["shared_down"], swiglu(g, u))
    return y, aux


def _moe_ep(cfg: Any, p: PyTree, x: jax.Array, mesh: Any
            ) -> Tuple[jax.Array, jax.Array]:
    """shard_map wrapper: tokens sequence-sharded over the EP axis when
    S divides, token-sliced inside the region otherwise (decode)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.sharding import dp_axes, ep_axis_name
    ep_ax = ep_axis_name()
    ep = mesh.shape[ep_ax]
    b, s, d = x.shape
    # batch spec: largest prefix of the dp axes that divides b (decode
    # at global_batch=1 keeps the batch replicated)
    dp_list = []
    prod = 1
    for a in dp_axes(mesh):
        if b % (prod * mesh.shape[a]) == 0:
            dp_list.append(a)
            prod *= mesh.shape[a]
        else:
            break
    dp = tuple(dp_list) if dp_list else None
    expert_spec = {"w": P("model", None, None)}
    p_specs = {
        "router": {"w": P(None, None)},
        "w_gate": expert_spec, "w_up": expert_spec, "w_down": expert_spec,
    }
    p_ep = {k: p[k] for k in p_specs}

    if s % ep == 0:
        x_spec = P(dp, ep_ax, None)

        def body(p_, x_):
            xf = x_.reshape(-1, d)
            y, aux = _moe_ep_shard(cfg, p_, xf, ep_ax,
                                   cfg_a2a_backend(cfg))
            return y.reshape(x_.shape), lax.pmean(aux, ep_ax)

        y, aux = shard_map(
            body, mesh=mesh, in_specs=(p_specs, x_spec),
            out_specs=(x_spec, P()), check_rep=False)(p_ep, x)
        return y.reshape(-1, d), aux

    # decode / non-divisible: tokens replicated over EP axis; each rank
    # takes a padded slice, computes, and the results are summed back.
    x_spec = P(dp, None, None)

    def body(p_, x_):
        xf = x_.reshape(-1, d)
        T = xf.shape[0]
        Tp = -(-T // ep) * ep
        xp = jnp.pad(xf, ((0, Tp - T), (0, 0)))
        rank = lax.axis_index(ep_ax)
        mine = lax.dynamic_slice_in_dim(xp, rank * (Tp // ep), Tp // ep, 0)
        y_loc, aux = _moe_ep_shard(cfg, p_, mine, ep_ax,
                                   cfg_a2a_backend(cfg))
        # place local slice into the padded buffer, sum over ranks
        yp = jnp.zeros((Tp, d), y_loc.dtype)
        yp = lax.dynamic_update_slice_in_dim(yp, y_loc, rank * (Tp // ep), 0)
        yp = lax.psum(yp, ep_ax)
        return yp[:T].reshape(x_.shape), lax.pmean(aux, ep_ax)

    y, aux = shard_map(
        body, mesh=mesh, in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()), check_rep=False)(p_ep, x)
    return y.reshape(-1, d), aux


def cfg_a2a_backend(cfg: Any) -> str:
    """LCX a2a lowering: 'native' (lax.all_to_all HLO) or 'pairwise'
    (ring of LCX puts).  Tunable per config for the perf loop."""
    return getattr(cfg, "moe_a2a", "native")


# ---------------------------------------------------------------------------
# resident-expert decode (beyond-paper, EXPERIMENTS.md §Perf iteration 6)
# ---------------------------------------------------------------------------
RESIDENT_BUDGET_BYTES = 6 * 1024 ** 3     # HBM share for resident experts


def resident_axes(mesh: Any, E: int) -> Tuple[Tuple[str, ...], int]:
    """Longest (dp..., model) prefix whose product divides E — the joint
    axis set expert weights can shard over so they stay RESIDENT on
    device for decode (no FSDP weight streaming).  dsv3: 256 experts /
    256 chips = 1 resident expert per device."""
    from repro.parallel.sharding import dp_axes
    axes = []
    prod = 1
    # model-first, then data, then pod: on the multi-pod mesh dsv3's 256
    # experts land on (model, data) = 256 and stay replicated across
    # pods (pod-local expert routing, no inter-pod dispatch)
    for a in ("model", *reversed(dp_axes(mesh))):
        if a in mesh.shape and E % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
        else:
            break
    return tuple(axes), prod


def resident_plan(cfg: Any, mesh: Any) -> Optional[Tuple[str, ...]]:
    """Axes for resident-expert decode, or None when the per-device
    resident slab would not fit the HBM budget (e.g. jamba's 16 fat
    experts across 256 chips -> 1.2 GiB x 36 layers: stream instead)."""
    if not cfg.n_experts:
        return None
    axes, n = resident_axes(mesh, cfg.n_experts)
    if n <= 1:
        return None
    n_moe_layers = sum(1 for spec in cfg.layer_plan()
                       if spec.ffn == "moe")
    per_dev = (cfg.n_experts // n) * 3 * cfg.d_model * cfg.moe_d_ff \
        * jnp.dtype(cfg.param_dtype).itemsize * n_moe_layers
    if per_dev > RESIDENT_BUDGET_BYTES:
        return None
    return axes


def _moe_resident_decode(cfg: Any, p: PyTree, x_flat: jax.Array,
                         mesh: Any) -> Tuple[jax.Array, jax.Array]:
    """Decode MoE with resident experts: tokens are replicated (tiny at
    decode), every rank routes identically, slices the capacity buffer
    rows of ITS resident experts, runs the FFN with fully local weights
    (zero weight movement), and the combined output is one small psum
    over the expert-owner axes."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    E = cfg.n_experts
    d = x_flat.shape[-1]
    axes, n_owner = resident_axes(mesh, E)
    E_loc = E // n_owner
    wspec = {"w": P(axes if len(axes) > 1 else axes[0], None, None)}
    p_specs = {"router": {"w": P(None, None)},
               "w_gate": wspec, "w_up": wspec, "w_down": wspec}
    p_ep = {k: p[k] for k in p_specs}

    def body(p_, xf):
        rank = jnp.int32(0)
        for a in axes:
            rank = rank * mesh.shape[a] + lax.axis_index(a)
        ids, w, aux = route(cfg, p_["router"], xf)
        C = capacity(cfg, xf.shape[0])
        buf, info = dispatch(xf, ids, w, E, C)          # [E, C, d] (repl.)
        mine = lax.dynamic_slice_in_dim(buf, rank * E_loc, E_loc, 0)
        yb_loc = _expert_ffn(p_, mine, 0, E_loc)        # resident weights
        yb = jnp.zeros((E, C, d), yb_loc.dtype)
        yb = lax.dynamic_update_slice_in_dim(yb, yb_loc, rank * E_loc, 0)
        yb = lax.psum(yb, axes)                         # small at decode
        return combine(yb, info, d), aux

    y, aux = shard_map(
        body, mesh=mesh, in_specs=(p_specs, P(None, None)),
        out_specs=(P(None, None), P()), check_rep=False)(p_ep, x_flat)
    return y, aux
