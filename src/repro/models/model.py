"""Model builder: embed → (prefix layers + scanned periodic stack) → head.

Layer plans come from ``ModelConfig.layer_plan()`` (dense / MoE / SSM /
hybrid / MLA / encoder-only).  The periodic part of the stack is executed
with ``lax.scan`` over stacked parameters (compact HLO, one compiled body
per period) and rematerialized according to ``cfg.remat``.

Three entry points per model:
- :func:`apply_model` — full-sequence forward (train / eval / prefill
  logits), returns ``(logits, aux)``.
- :func:`loss_fn` — next-token cross entropy + MoE aux + optional MTP.
- :func:`init_cache` / :func:`prefill` / :func:`decode_step` — serving.

Activation sharding constraints are applied at layer boundaries via
`repro.parallel.sharding.constrain` (logical names → mesh axes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import (PyTree, dense, dense_init, embed, embed_init, gelu,
                     merge, norm, norm_init, softmax_xent, swiglu)
from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .attention import (attn_apply, attn_cache_init, attn_decode, attn_init)
from .mla import mla_apply, mla_cache_init, mla_decode, mla_init
from .moe import moe_apply, moe_init
from .ssm import ssm_apply, ssm_cache_init, ssm_decode, ssm_init


def _constrain(x: jax.Array, dims: Tuple[Optional[str], ...]) -> jax.Array:
    from repro.parallel.sharding import constrain
    return constrain(x, dims)


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------
def ffn_init(key: jax.Array, cfg: Any) -> Tuple[PyTree, PyTree]:
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return merge(
            ("gate", dense_init(ks[0], cfg.d_model, cfg.d_ff,
                                dims=("embed", "mlp"),
                                dtype=cfg.param_dtype)),
            ("up", dense_init(ks[1], cfg.d_model, cfg.d_ff,
                              dims=("embed", "mlp"),
                              dtype=cfg.param_dtype)),
            ("down", dense_init(ks[2], cfg.d_ff, cfg.d_model,
                                dims=("mlp", "embed"),
                                dtype=cfg.param_dtype)),
        )
    return merge(
        ("fc1", dense_init(ks[0], cfg.d_model, cfg.d_ff,
                           dims=("embed", "mlp"), bias=True,
                           dtype=cfg.param_dtype)),
        ("fc2", dense_init(ks[1], cfg.d_ff, cfg.d_model,
                           dims=("mlp", "embed"), bias=True,
                           dtype=cfg.param_dtype)),
    )


def ffn_apply(cfg: Any, p: PyTree, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        h = swiglu(dense(p["gate"], x), dense(p["up"], x))
        h = _constrain(h, ("batch", None, "mlp"))
        return dense(p["down"], h)
    h = gelu(dense(p["fc1"], x))
    h = _constrain(h, ("batch", None, "mlp"))
    return dense(p["fc2"], h)


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------
def layer_init(key: jax.Array, cfg: Any, spec: Any) -> Tuple[PyTree, PyTree]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    parts = [("norm1", norm_init(cfg.norm, cfg.d_model, cfg.param_dtype))]
    if spec.mixer == "attn":
        parts.append(("mixer", attn_init(k1, cfg)))
    elif spec.mixer == "mla":
        parts.append(("mixer", mla_init(k1, cfg)))
    else:
        parts.append(("mixer", ssm_init(k1, cfg)))
    if spec.ffn is not None:
        parts.append(("norm2", norm_init(cfg.norm, cfg.d_model,
                                         cfg.param_dtype)))
        if spec.ffn == "moe":
            parts.append(("ffn", moe_init(k2, cfg)))
        else:
            parts.append(("ffn", ffn_init(k2, cfg)))
    return merge(*parts)


def layer_cache_init(cfg: Any, spec: Any, batch: int, max_seq: int) -> PyTree:
    if spec.mixer == "attn":
        return attn_cache_init(cfg, batch, max_seq)
    if spec.mixer == "mla":
        return mla_cache_init(cfg, batch, max_seq)
    return ssm_cache_init(cfg, batch)


def layer_apply(cfg: Any, spec: Any, p: PyTree, x: jax.Array, *,
                positions: jax.Array, mode: str = "train",
                cache: Optional[PyTree] = None,
                length: Optional[jax.Array] = None,
                impl: Optional[str] = None,
                kernels: Optional[Dict[str, Any]] = None
                ) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """-> (x_out, new_cache | None, aux_loss)."""
    impl = impl or getattr(cfg, "attn_impl", "chunked")
    kernels = kernels or {}
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    h = norm(cfg.norm, p["norm1"], x, cfg.norm_eps)

    if spec.mixer == "attn":
        if mode == "decode":
            y, new_cache = attn_decode(cfg, p["mixer"], h, cache, length)
        else:
            y = attn_apply(cfg, p["mixer"], h, positions=positions,
                           impl=impl,
                           kernel_fn=kernels.get("flash_attention"))
            if mode == "prefill":
                new_cache = _attn_fill_cache(cfg, p["mixer"], h, positions,
                                             cache)
    elif spec.mixer == "mla":
        if mode == "decode":
            y, new_cache = mla_decode(cfg, p["mixer"], h, cache, length)
        else:
            y = mla_apply(cfg, p["mixer"], h, positions=positions, impl=impl)
            if mode == "prefill":
                new_cache = _mla_fill_cache(cfg, p["mixer"], h, positions,
                                            cache)
    else:  # mamba
        if mode == "decode":
            y, new_cache = ssm_decode(cfg, p["mixer"], h, cache)
        else:
            y, state = ssm_apply(cfg, p["mixer"], h,
                                 return_cache=(mode == "prefill"),
                                 kernel_fn=kernels.get("ssd_scan"))
            if mode == "prefill":
                new_cache = state
    if mode != "decode":
        # pin the row-parallel partial-sum output to the seq-sharded
        # layout BEFORE the residual add: GSPMD then lowers the psum as
        # a reduce-scatter instead of all-reduce+slice (§Perf iter. 4)
        y = _constrain(y, ("batch", "seq", "embed"))
    x = x + y
    x = _constrain(x, ("batch", "seq", "embed"))

    if spec.ffn is not None:
        h = norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        if spec.ffn == "moe":
            y, aux = moe_apply(cfg, p["ffn"], h)
        else:
            y = ffn_apply(cfg, p["ffn"], h)
        if mode != "decode":
            y = _constrain(y, ("batch", "seq", "embed"))
        x = x + y
        x = _constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


def _attn_fill_cache(cfg: Any, p: PyTree, h: jax.Array,
                     positions: jax.Array, cache: PyTree) -> PyTree:
    k = dense(p["wk"], h).reshape(h.shape[0], h.shape[1], cfg.n_kv_heads,
                                  cfg.head_dim)
    v = dense(p["wv"], h).reshape(h.shape[0], h.shape[1], cfg.n_kv_heads,
                                  cfg.head_dim)
    if cfg.qk_norm:
        k = norm("rms", p["knorm"], k, cfg.norm_eps)
    from .common import rope_cos_sin, apply_rope
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    k = apply_rope(k, cos, sin)
    s = h.shape[1]
    return {
        "k": lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }


def _mla_fill_cache(cfg: Any, p: PyTree, h: jax.Array,
                    positions: jax.Array, cache: PyTree) -> PyTree:
    c_kv, k_rope = mla_mod._latents(cfg, p, h, positions)
    return {
        "ckv": lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, 0, 0)),
        "krope": lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, 0, 0)),
    }


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------
def init_model(key: jax.Array, cfg: Any) -> Tuple[PyTree, PyTree]:
    prefix, period, n_periods = cfg.scan_plan()
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    dims: Dict[str, Any] = {}

    if cfg.frontend is None or cfg.family != "audio":
        p, d = embed_init(keys[0], cfg.vocab, cfg.d_model,
                          dtype=cfg.param_dtype)
        params["embed"], dims["embed"] = p, d

    # prefix layers (individual)
    for i, spec in enumerate(prefix):
        p, d = layer_init(jax.random.fold_in(keys[1], i), cfg, spec)
        params[f"prefix_{i}"], dims[f"prefix_{i}"] = p, d

    # scanned periodic body: stack n_periods copies
    def init_period(k):
        ps, ds = {}, {}
        for j, spec in enumerate(period):
            p, d = layer_init(jax.random.fold_in(k, j), cfg, spec)
            ps[f"l{j}"], ds[f"l{j}"] = p, d
        return ps, ds

    period_keys = jax.random.split(keys[2], n_periods)
    stacked = jax.vmap(lambda k: init_period(k)[0])(period_keys)
    _, period_dims = init_period(period_keys[0])
    params["stack"] = stacked
    dims["stack"] = jax.tree.map(
        lambda t: ("layers",) + t if isinstance(t, tuple) else t,
        period_dims, is_leaf=lambda t: isinstance(t, tuple))

    p, d = norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)
    params["final_norm"], dims["final_norm"] = p, d

    if not cfg.tie_embeddings:
        p, d = dense_init(keys[3], cfg.d_model, cfg.vocab,
                          dims=("embed", "vocab"), dtype=cfg.param_dtype)
        params["head"], dims["head"] = p, d

    if cfg.mtp_depth:
        from repro.configs.base import LayerSpec
        p, d = layer_init(keys[4], cfg,
                          LayerSpec("attn" if cfg.family != "ssm"
                                    else "mamba", "dense"))
        params["mtp_layer"], dims["mtp_layer"] = p, d
        p, d = dense_init(keys[5], 2 * cfg.d_model, cfg.d_model,
                          dims=("embed", "embed_out"),
                          dtype=cfg.param_dtype)
        params["mtp_proj"], dims["mtp_proj"] = p, d
        p, d = norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)
        params["mtp_norm"], dims["mtp_norm"] = p, d
    return params, dims


def abstract_init(cfg: Any, key: Optional[jax.Array] = None
                  ) -> Tuple[PyTree, PyTree]:
    """(ShapeDtypeStruct params, dims) without allocating anything —
    the dry-run / trainer-construction path for huge configs."""
    key = jax.random.PRNGKey(0) if key is None else key
    captured: Dict[str, Any] = {}

    def f(k):
        p, d = init_model(k, cfg)
        captured["dims"] = d
        return p

    params_proto = jax.eval_shape(f, key)
    return params_proto, captured["dims"]


def _embed_in(cfg: Any, params: PyTree, tokens: jax.Array,
              frontend_embeds: Optional[jax.Array]) -> jax.Array:
    if cfg.family == "audio":
        # encoder stub: inputs ARE frame embeddings [B, S, D]
        return frontend_embeds.astype(cfg.dtype)
    x = embed(params["embed"], tokens, cfg.dtype)
    if frontend_embeds is not None:       # VLM: prepend patch embeddings
        x = jnp.concatenate([frontend_embeds.astype(cfg.dtype), x], axis=1)
    return x


def _head_out(cfg: Any, params: PyTree, x: jax.Array) -> jax.Array:
    x = norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["emb"].T.astype(x.dtype)
    else:
        logits = dense(params["head"], x)
    return _constrain(logits, ("batch", "seq", "vocab"))


def _stack_sweep(cfg: Any, params: PyTree, x: jax.Array, *,
                 positions: jax.Array, mode: str,
                 caches: Optional[PyTree] = None,
                 length: Optional[jax.Array] = None,
                 impl: Optional[str] = None,
                 kernels: Optional[Dict[str, Any]] = None
                 ) -> Tuple[jax.Array, jax.Array, Optional[PyTree]]:
    """Run prefix + scanned stack.  Returns (x, aux, new_caches)."""
    prefix, period, n_periods = cfg.scan_plan()
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}

    for i, spec in enumerate(prefix):
        c = None if caches is None else caches[f"prefix_{i}"]
        x, nc, aux = layer_apply(cfg, spec, params[f"prefix_{i}"], x,
                                 positions=positions, mode=mode, cache=c,
                                 length=length, impl=impl, kernels=kernels)
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[f"prefix_{i}"] = nc

    def period_body(carry, inp):
        x_, aux_ = carry
        p_stack = inp["params"]
        c_stack = inp.get("cache")
        ncs: Dict[str, Any] = {}
        for j, spec in enumerate(period):
            c = None if c_stack is None else c_stack[f"l{j}"]
            x_, nc, a = layer_apply(cfg, spec, p_stack[f"l{j}"], x_,
                                    positions=positions, mode=mode,
                                    cache=c, length=length, impl=impl,
                                    kernels=kernels)
            aux_ = aux_ + a
            if nc is not None:
                ncs[f"l{j}"] = nc
        return (x_, aux_), (ncs if ncs else 0)

    body = period_body
    if mode == "train" and cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full"
                  else jax.checkpoint_policies.checkpoint_dots)
        body = jax.checkpoint(period_body, policy=policy,
                              prevent_cse=False)

    xs: Dict[str, Any] = {"params": params["stack"]}
    if caches is not None:
        xs["cache"] = caches["stack"]
    (x, aux_total), stack_caches = lax.scan(body, (x, aux_total), xs)
    if mode in ("prefill", "decode"):
        new_caches["stack"] = stack_caches
        return x, aux_total, new_caches
    return x, aux_total, None


def apply_model(cfg: Any, params: PyTree, tokens: jax.Array, *,
                frontend_embeds: Optional[jax.Array] = None,
                impl: Optional[str] = None,
                kernels: Optional[Dict[str, Any]] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  tokens [B, S] -> (logits [B, S', V], aux)."""
    x = _embed_in(cfg, params, tokens, frontend_embeds)
    x = _constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux, _ = _stack_sweep(cfg, params, x, positions=positions,
                             mode="train", impl=impl, kernels=kernels)
    return _head_out(cfg, params, x), aux


def loss_fn(cfg: Any, params: PyTree, batch: Dict[str, jax.Array], *,
            impl: Optional[str] = None,
            kernels: Optional[Dict[str, Any]] = None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = apply_model(cfg, params, batch["tokens"],
                              frontend_embeds=batch.get("frontend"),
                              impl=impl, kernels=kernels)
    labels = batch["labels"]
    if cfg.family == "vlm" and "frontend" in batch:
        logits = logits[:, batch["frontend"].shape[1]:, :]
    xent = softmax_xent(logits, labels, batch.get("mask"))
    loss = xent + cfg.aux_loss_coef * aux
    metrics = {"xent": xent, "aux": aux}
    if cfg.mtp_depth:
        mtp = _mtp_loss(cfg, params, batch, logits)
        loss = loss + cfg.mtp_loss_coef * mtp
        metrics["mtp"] = mtp
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(cfg: Any, params: PyTree, batch: Dict[str, jax.Array],
              logits: jax.Array) -> jax.Array:
    """DeepSeek-V3 multi-token prediction (depth 1, simplified): combine
    hidden-ish signal (re-embedded argmax-free: use token embeddings) with
    the next token's embedding, one extra layer, predict t+2."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, cfg.dtype)
    nxt = jnp.roll(x, -1, axis=1)
    h = dense(params["mtp_proj"], jnp.concatenate([x, nxt], axis=-1))
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    from repro.configs.base import LayerSpec
    spec = LayerSpec("attn" if cfg.family != "ssm" else "mamba", "dense")
    h, _, _ = layer_apply(cfg, spec, params["mtp_layer"], h,
                          positions=positions, mode="train")
    h = norm(cfg.norm, params["mtp_norm"], h, cfg.norm_eps)
    mtp_logits = _head_out(cfg, params, h)
    labels2 = jnp.roll(batch["labels"], -1, axis=1)
    mask = jnp.ones_like(labels2, jnp.float32).at[:, -2:].set(0.0)
    return softmax_xent(mtp_logits, labels2, mask)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_cache(cfg: Any, batch: int, max_seq: int) -> PyTree:
    prefix, period, n_periods = cfg.scan_plan()
    caches: Dict[str, Any] = {}
    for i, spec in enumerate(prefix):
        caches[f"prefix_{i}"] = layer_cache_init(cfg, spec, batch, max_seq)

    def one_period(_):
        return {f"l{j}": layer_cache_init(cfg, spec, batch, max_seq)
                for j, spec in enumerate(period)}

    caches["stack"] = jax.vmap(one_period)(jnp.arange(n_periods))
    return caches


def cache_batch_axes(cfg: Any, caches: PyTree) -> PyTree:
    """Pytree (matching ``caches``) of the batch-dim index per leaf:
    0 for prefix-layer caches, 1 for scan-stacked caches (dim 0 is the
    period index there).  Used by the serving engine for slot indexing
    and by vmapped decode."""
    return {k: jax.tree.map(lambda _: 1 if k == "stack" else 0, v)
            for k, v in caches.items()}


def prefill(cfg: Any, params: PyTree, tokens: jax.Array, caches: PyTree, *,
            frontend_embeds: Optional[jax.Array] = None,
            impl: Optional[str] = None,
            kernels: Optional[Dict[str, Any]] = None
            ) -> Tuple[jax.Array, PyTree]:
    """Fill the cache for the prompt; return (last-position logits, cache)."""
    x = _embed_in(cfg, params, tokens, frontend_embeds)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, new_caches = _stack_sweep(cfg, params, x, positions=positions,
                                    mode="prefill", caches=caches,
                                    impl=impl, kernels=kernels)
    logits = _head_out(cfg, params, x[:, -1:, :])
    return logits, new_caches


def decode_step(cfg: Any, params: PyTree, tokens: jax.Array, caches: PyTree,
                length: jax.Array, *,
                kernels: Optional[Dict[str, Any]] = None
                ) -> Tuple[jax.Array, PyTree]:
    """One token for every sequence.  tokens [B, 1]; length [] = current
    cache fill.  Returns (logits [B, 1, V], new caches)."""
    x = _embed_in(cfg, params, tokens, None)
    positions = jnp.full((1,), length, jnp.int32)
    x, _, new_caches = _stack_sweep(cfg, params, x, positions=positions,
                                    mode="decode", caches=caches,
                                    length=length, kernels=kernels)
    return _head_out(cfg, params, x), new_caches
