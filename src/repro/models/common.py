"""Shared model building blocks (pure JAX, functional params).

Params are nested dicts of arrays.  Every init function returns a pair
``(params, dims)`` where ``dims`` mirrors the params tree with a tuple of
*logical dimension names* per leaf — the sharding layer
(`repro.parallel.sharding`) maps logical names to mesh axes.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key: jax.Array, d_in: int, d_out: int, *, dims: Tuple[str, str],
               bias: bool = False, scale: Optional[float] = None,
               dtype: Any = jnp.float32) -> Tuple[PyTree, PyTree]:
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    d = {"w": dims}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        d["b"] = (dims[1],)
    return p, d


def dense(p: PyTree, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_init(key: jax.Array, vocab: int, d: int, *,
               dtype: Any = jnp.float32) -> Tuple[PyTree, PyTree]:
    p = {"emb": (jax.random.normal(key, (vocab, d), jnp.float32)
                 * 0.02).astype(dtype)}
    return p, {"emb": ("vocab", "embed")}


def embed(p: PyTree, tokens: jax.Array, dtype: Any) -> jax.Array:
    return jnp.take(p["emb"], tokens, axis=0).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype: Any = jnp.float32) -> Tuple[PyTree, PyTree]:
    return {"g": jnp.ones((d,), dtype)}, {"g": ("embed",)}


def rmsnorm(p: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    n = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * p["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype: Any = jnp.float32) -> Tuple[PyTree, PyTree]:
    return ({"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
            {"g": ("embed",), "b": ("embed",)})


def layernorm(p: PyTree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    n = (xf - mu) * lax.rsqrt(var + eps)
    return (n * p["g"].astype(jnp.float32)
            + p["b"].astype(jnp.float32)).astype(x.dtype)


def norm_init(kind: str, d: int, dtype: Any = jnp.float32):
    return rmsnorm_init(d, dtype) if kind == "rms" else layernorm_init(d, dtype)


def norm(kind: str, p: PyTree, x: jax.Array, eps: float) -> jax.Array:
    return rmsnorm(p, x, eps) if kind == "rms" else layernorm(p, x, eps)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [*S] -> cos,sin [*S, head_dim//2] (fp32)."""
    ang = positions.astype(jnp.float32)[..., None] * rope_freqs(head_dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, H, D]; cos/sin [S, D/2] (broadcast over heads)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; logits [..., V] fp32-reduced."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None],
                             axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# tree utilities for (params, dims) pairs
# ---------------------------------------------------------------------------
def merge(*pairs: Tuple[str, Tuple[PyTree, PyTree]]
          ) -> Tuple[Dict[str, PyTree], Dict[str, PyTree]]:
    """merge(("attn", (p,d)), ("mlp", (p,d))) -> ({...}, {...})"""
    params: Dict[str, PyTree] = {}
    dims: Dict[str, PyTree] = {}
    for name, (p, d) in pairs:
        params[name] = p
        dims[name] = d
    return params, dims


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: PyTree) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree.leaves(params))
