"""Pallas TPU grouped matmul for MoE expert FFN.

Capacity-format GMM: xb [E, C, d] @ w [E, d, f] -> [E, C, f] with grid
(E, C/bc, f/bf, d/bd) and an f32 VMEM accumulator across the contracting
sweep (innermost grid dim).  MXU-aligned 128-multiples blocks; one
expert per grid slice so expert weights stream through VMEM once per
(ci, fj) tile pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    dk = pl.program_id(3)

    @pl.when(dk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(dk == nd - 1)
    def _fin():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "block_d", "interpret"))
def moe_gmm(xb: jax.Array, w: jax.Array, *, block_c: int = 256,
            block_f: int = 512, block_d: int = 512,
            interpret: bool = False) -> jax.Array:
    """xb [E, C, d] @ w [E, d, f] -> [E, C, f]."""
    e, c, d = xb.shape
    f = w.shape[-1]
    bc, bf, bd = _pick(block_c, c), _pick(block_f, f), _pick(block_d, d)
    nd = d // bd
    kernel = functools.partial(_gmm_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(e, c // bc, f // bf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ei, ci, fj, dk: (ei, ci, dk)),
            pl.BlockSpec((1, bd, bf), lambda ei, ci, fj, dk: (ei, dk, fj)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ei, ci, fj, dk: (ei, ci, fj)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), xb.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(xb, w)
