"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid = (B, H, nc) with the chunk index innermost, so the inter-chunk
state h [N, P] lives in VMEM scratch and carries across the sequential
chunk sweep (the TPU grid is executed in order) — the recurrence never
round-trips to HBM.  Within a chunk the quadratic term uses two MXU
matmuls ([cs,N]@[N,cs] and [cs,cs]@[cs,P]); cs defaults to 128/256 so
every matmul dim is MXU-aligned.

All decay arithmetic in f32; the decays are exp of non-positive sums.

Layout: the wrapper (`repro.kernels.ops.ssd_scan`) reshapes the model's
[B,S,H,*] tensors to chunked head-major [B,H,nc,cs,*] so blocks are
contiguous along the trailing two dims.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, cs: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)           # [cs, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)         # [cs, 1]
    A = a_ref[0, 0]                                  # scalar f32
    Bm = b_ref[0, 0, 0].astype(jnp.float32)          # [cs, N]
    Cm = c_ref[0, 0, 0].astype(jnp.float32)          # [cs, N]

    dA = dt * A                                      # [cs,1] (<= 0)
    cum = jnp.cumsum(dA, axis=0)                     # [cs,1]
    cum_last = cum[cs - 1]                           # [1]

    # within-chunk quadratic term
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    Lmat = jnp.exp(cum - cum.T)                      # [cs, cs]
    rows = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    w = jnp.where(rows >= cols, scores * Lmat * dt.T, 0.0)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # carried-state contribution: C_i · h * exp(cum_i)
    h = h_ref[...]                                   # [N, P]
    y = y + jax.lax.dot_general(Cm, h, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(cum)

    # state update: h' = h*exp(sum dA) + sum_j decay_j dt_j B_j x_j^T
    decay_end = jnp.exp(cum_last[None, :] - cum)     # [cs,1]
    bw = Bm * (decay_end * dt)                       # [cs, N]
    Sc = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h_ref[...] = h * jnp.exp(cum_last)[0] + Sc

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        hout_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                     Bm: jax.Array, Cm: jax.Array, *,
                     interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
    """Chunk-major SSD.  x [B,H,nc,cs,P], dt [B,H,nc,cs,1], A [H,1],
    B/C [B,H,nc,cs,N].  Returns (y like x, h_final [B,H,N,P] f32)."""
    b, h, nc, cs, p = x.shape
    n = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, cs=cs, nc=nc)
    y, hout = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, cs, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, cs, 1),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, 1, cs, n),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, cs, n),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, cs, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, cs, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, hout
