"""Pallas TPU flash attention (GQA) — online-softmax with VMEM blocking.

Grid = (B, Hq, nq, nk); the innermost (fastest) grid dimension sweeps KV
blocks so the f32 accumulator/m/l scratch in VMEM carries across the
sweep for one (batch, head, q-block).  Block shapes are MXU-aligned
multiples of 128 on the Sq/Sk dims; head_dim rides the lane dimension.

Causal blocks strictly above the diagonal are skipped with ``pl.when``
(no MXU issue on TPU; correctness-neutral in interpret mode).

VMEM working set per grid point:
    q (bq·Dk) + k (bk·Dk) + v (bk·Dv) + acc (bq·Dv f32) + s (bq·bk f32)
with defaults bq=bk=256, Dk=Dv=128: ~0.7 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    kj = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks strictly above the diagonal
    run = (qi * bq + bq - 1 >= kj * bk) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [bq, dk]
        k = k_ref[0, 0].astype(jnp.float32)           # [bk, dk]
        v = v_ref[0, 0]                               # [bk, dv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                           # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [bq, dv]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q [B,Hq,Sq,Dk], k [B,Hkv,Sk,Dk], v [B,Hkv,Sk,Dv] -> [B,Hq,Sq,Dv]."""
    b, hq, sq, dk = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    scale = (dk ** -0.5) if scale is None else scale
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_k, sk)
    while sk % bk:
        bk //= 2
    nq, nk = sq // bq, sk // bk

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dk), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dk),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
