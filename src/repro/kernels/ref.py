"""Pure-jnp oracles for every Pallas kernel (the contract each kernel is
validated against, shape/dtype-swept in tests/test_kernels_*.py)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        scale: Optional[float] = None) -> jax.Array:
    """q [B,Hq,Sq,Dk], k [B,Hkv,Sk,Dk], v [B,Hkv,Sk,Dv] -> [B,Hq,Sq,Dv].
    GQA via head grouping (Hq % Hkv == 0)."""
    b, hq, sq, dk = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = (dk ** -0.5) if scale is None else scale
    qg = q.reshape(b, hkv, g, sq, dk)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, v.shape[-1]).astype(v.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, chunk: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (non-chunked) SSD recurrence — the gold reference.

    x [B,S,H,P], dt [B,S,H] (>=0), A [H] (<0), B/C [B,S,H,N].
    Returns y [B,S,H,P], h_final [B,H,N,P]."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    f32 = jnp.float32

    def step(hstate, inp):
        xt, dtt, bt, ct = inp                     # [b,h,*]
        decay = jnp.exp(dtt.astype(f32) * A)      # [b,h]
        hstate = hstate * decay[..., None, None] \
            + jnp.einsum("bh,bhn,bhp->bhnp", dtt.astype(f32),
                         bt.astype(f32), xt.astype(f32))
        y = jnp.einsum("bhn,bhnp->bhp", ct.astype(f32), hstate)
        return hstate, y

    h0 = jnp.zeros((b, h, n, p), f32)
    hf, ys = lax.scan(step, h0,
                      (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                       Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hf


def moe_gmm_ref(xb: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped (expert-batched) matmul: [E,C,d] @ [E,d,f] -> [E,C,f]."""
    return jnp.einsum("ecd,edf->ecf", xb.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(xb.dtype)


def ring_allgather_ref(x: jax.Array, axis: str) -> jax.Array:
    """Under shard_map: x [1, ...] per device -> [n, ...]."""
    return lax.all_gather(x[0], axis, tiled=False)
