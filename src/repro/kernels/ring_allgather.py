"""Pallas TPU ring all-gather — LCX ``put`` with remote signal at the
metal: ``pltpu.make_async_remote_copy`` is RDMA-write-with-signal (the
paper §2.2's put + remote completion object), and the DMA semaphores are
the completion objects.

Each device forwards the slot it received on the previous step to its
right neighbour; after n-1 steps every device holds every shard.  One
DMA in flight per step per device, send/recv semaphores as completion.

Validated on CPU with the TPU interpret machinery
(``pltpu.InterpretParams(dma_execution_mode="eager")`` — eager matches
real hardware, where the DMA read engine snapshots the source at
``start()``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def tpu_interpret_available() -> bool:
    """True when this JAX release carries the TPU interpret machinery the
    ring kernel needs on CPU (``InterpretParams`` + ``sync_copy``).
    Older pins (e.g. 0.4.x) lack both; callers should skip/fallback."""
    return (hasattr(pltpu, "InterpretParams")
            and hasattr(pltpu, "sync_copy"))


def _ring_kernel(x_ref, o_ref, send_sem, recv_sem, *, axis: str, n: int):
    my_id = lax.axis_index(axis)
    # local shard into my slot (LCX loopback put)
    pltpu.sync_copy(x_ref, o_ref.at[pl.ds(my_id, 1)])
    for step in range(n - 1):
        slot = (my_id - step) % n
        rdc = pltpu.make_async_remote_copy(
            src_ref=o_ref.at[pl.ds(slot, 1)],
            dst_ref=o_ref.at[pl.ds(slot, 1)],
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=(my_id + 1) % n,
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdc.start()           # post the LCX put
        rdc.wait()            # completion: send drained + slot arrived


def ring_all_gather(x: jax.Array, axis: str, *, axis_size: int,
                    interpret: bool = True) -> jax.Array:
    """Under shard_map: x [1, ...] (this device's shard, leading axis 1)
    -> [axis_size, ...] (all shards).  TPU-only at scale; interpret mode
    simulates the DMAs on CPU."""
    n = axis_size
    if interpret and not tpu_interpret_available():
        raise NotImplementedError(
            "ring_all_gather interpret mode needs pltpu.InterpretParams "
            "and pltpu.sync_copy, absent from the pinned JAX release — "
            "run on real TPU or upgrade JAX")
    kernel = functools.partial(_ring_kernel, axis=axis, n=n)
    ip = pltpu.InterpretParams(dma_execution_mode="eager") \
        if interpret else False
    # CompilerParams was TPUCompilerParams before the rename
    cp_cls = getattr(pltpu, "CompilerParams",
                     getattr(pltpu, "TPUCompilerParams", None))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,) + x.shape[1:], x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        interpret=ip,
        compiler_params=cp_cls(
            collective_id=7) if not interpret and cp_cls else None,
    )(x)
