"""Public kernel entry points with backend selection.

``backend`` resolution per call:
- ``"pallas"``  — compiled Pallas (TPU) or interpret mode on CPU;
- ``"xla"``     — the ref.py oracle (pure jnp, what the dry-run lowers);
- ``None``      — auto: compiled Pallas on TPU, ``xla`` elsewhere (the
  dry-run's CPU placeholder devices cannot compile Mosaic kernels).

``model_kernels(cfg)`` builds the kernels dict consumed by
`repro.models` (signatures match ``attn_apply``/``ssm_apply`` hooks).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref
from .flash_attention import flash_attention as _flash_pallas
from .moe_gmm import moe_gmm as _gmm_pallas
from .ring_allgather import ring_all_gather as _ring_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: Optional[str]) -> str:
    if backend is None:
        return "pallas" if on_tpu() else "xla"
    return backend


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 256, block_k: int = 256,
                    backend: Optional[str] = None) -> jax.Array:
    """[B,Hq,Sq,Dk] x [B,Hkv,Sk,Dk] x [B,Hkv,Sk,Dv] -> [B,Hq,Sq,Dv]."""
    be = _resolve(backend)
    if be == "xla":
        return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    return _flash_pallas(q, k, v, causal=causal,
                         scale=(q.shape[-1] ** -0.5 if scale is None
                                else scale),
                         block_q=block_q, block_k=block_k,
                         interpret=not on_tpu())


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 256,
             backend: Optional[str] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Model-layout SSD.  x [B,S,H,P], dt [B,S,H], A [H],
    B/C [B,S,H,N] -> (y [B,S,H,P], h_final [B,H,N,P])."""
    be = _resolve(backend)
    if be == "xla":
        from repro.models.ssm import ssd_chunked
        return ssd_chunked(x, dt, A, Bm, Cm, chunk)
    from .ssd_scan import ssd_scan_chunked
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    cs = min(chunk, s)
    while s % cs:
        cs //= 2
    nc = s // cs

    def chunked(t):  # [B,S,H,*] -> [B,H,nc,cs,*]
        t = jnp.moveaxis(t, 2, 1)
        return t.reshape((b, h, nc, cs) + t.shape[3:])

    y, hf = ssd_scan_chunked(
        chunked(x), chunked(dt[..., None]),
        A.astype(jnp.float32)[:, None], chunked(Bm), chunked(Cm),
        interpret=not on_tpu())
    y = jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)
    return y, hf


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------
def moe_gmm(xb: jax.Array, w: jax.Array, *,
            backend: Optional[str] = None) -> jax.Array:
    be = _resolve(backend)
    if be == "xla":
        return _ref.moe_gmm_ref(xb, w)
    return _gmm_pallas(xb, w, interpret=not on_tpu())


# ---------------------------------------------------------------------------
# ring all-gather (LCX put-with-signal ring)
# ---------------------------------------------------------------------------
def ring_all_gather(x: jax.Array, axis: str, *, axis_size: int,
                    backend: Optional[str] = None) -> jax.Array:
    be = _resolve(backend)
    if be == "xla":
        return _ref.ring_allgather_ref(x, axis)
    return _ring_pallas(x, axis, axis_size=axis_size,
                        interpret=not on_tpu())


# ---------------------------------------------------------------------------
# model hook adapters
# ---------------------------------------------------------------------------
def model_kernels(cfg: Any, backend: Optional[str] = None
                  ) -> Dict[str, Any]:
    """Kernels dict for `repro.models` hooks.

    - flash_attention hook signature: (q,k,v [B,S,H,D], causal, scale)
      -> [B,S,Hq,Dv]   (model layout: seq-major)
    - ssd_scan hook signature: (x,dt,A,B,C, chunk) -> (y, h_final)
    """
    def attn_hook(q, k, v, *, causal, scale):
        qT = jnp.swapaxes(q, 1, 2)
        kT = jnp.swapaxes(k, 1, 2)
        vT = jnp.swapaxes(v, 1, 2)
        o = flash_attention(qT, kT, vT, causal=causal, scale=scale,
                            block_q=cfg.q_block, block_k=cfg.q_block,
                            backend=backend)
        return jnp.swapaxes(o, 1, 2)

    def ssd_hook(x, dt, A, Bm, Cm, *, chunk):
        return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, backend=backend)

    return {"flash_attention": attn_hook, "ssd_scan": ssd_hook}
