"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a pure-jnp oracle
in ref.py, and a backend-selecting wrapper in ops.py.  Validated in
interpret mode on CPU (tests/test_kernels_*.py sweeps shapes/dtypes);
compiled Mosaic on real TPUs.  The ring all-gather is the LCX
put-with-remote-signal pattern at the DMA level.
"""
from . import ops, ref
from .ops import (flash_attention, model_kernels, moe_gmm, on_tpu,
                  ring_all_gather, ssd_scan)

__all__ = ["ops", "ref", "flash_attention", "model_kernels", "moe_gmm",
           "on_tpu", "ring_all_gather", "ssd_scan"]
