from .roofline import (RooflineReport, analyze_compiled, collective_bytes,
                       model_flops, parse_collectives)

__all__ = ["RooflineReport", "analyze_compiled", "collective_bytes",
           "model_flops", "parse_collectives"]
