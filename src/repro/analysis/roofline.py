"""Roofline analysis from compiled dry-run artifacts.

Per (arch × shape × mesh) we derive three per-device time terms:

    compute    = HLO_FLOPs / peak_FLOP/s            (197e12 bf16, v5e)
    memory     = HLO_bytes / HBM_bw                 (819e9 B/s)
    collective = wire_bytes / ICI_axis_bw           (2 × 50e9 B/s)

``HLO_FLOPs``/``HLO_bytes`` come from ``compiled.cost_analysis()`` of the
SPMD-partitioned per-device module.  ``collective`` is NOT in
cost_analysis: we parse the optimized HLO text and sum the wire bytes of
every collective op, using standard ring/all-to-all cost models:

    all-gather      out_bytes × (g-1)/g
    reduce-scatter  in_bytes  × (g-1)/g
    all-reduce      2 × bytes × (g-1)/g
    all-to-all      bytes × (g-1)/g
    collective-permute  bytes

where g is the replica-group size parsed from the op's
``replica_groups`` attribute (iota `[a,b]<=[n]` or explicit braces).

The dominant term is the bottleneck; ``MODEL_FLOPS / HLO_FLOPs`` exposes
remat/redundancy waste (< 1/3 for fwd+bwd means heavy recompute).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch.mesh import (HBM_BW, ICI_AXIS_BW, PEAK_FLOPS_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)\b", re.I)

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    dtype: str
    shape: Tuple[int, ...]
    bytes: int           # tensor bytes (per device output/input)
    group: int           # replica group size
    wire_bytes: float    # estimated bytes over ICI per device


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        inner = m.group(1).strip()
        return len([t for t in inner.split(",") if t.strip() != ""])
    return 1


def _wire(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    kind = kind.lower()
    if kind == "all-gather":
        return nbytes * frac            # nbytes = gathered (output) size
    if kind == "reduce-scatter":
        return nbytes * frac            # nbytes = input size (per device)
    if kind == "all-reduce":
        return 2.0 * nbytes * frac
    if kind == "all-to-all":
        return nbytes * frac
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if "-start" in line and (" = " in line):
            # avoid double counting start/done pairs: count -start only,
            # skip matching "-done"
            pass
        m = _COLL_RE.search(line)
        if not m:
            continue
        name, dtype, dims, kind = m.groups()
        if name.endswith("-done") or ".done" in name:
            continue
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims \
            else ()
        elems = int(np.prod(shape)) if shape else 1
        nbytes = elems * _DTYPE_BYTES[dtype]
        g = _group_size(line)
        ops.append(CollectiveOp(kind=kind.lower(), dtype=dtype,
                                shape=shape, bytes=nbytes, group=g,
                                wire_bytes=_wire(kind, nbytes, g)))
    return ops


def collective_bytes(hlo_text: str) -> float:
    return sum(op.wire_bytes for op in parse_collectives(hlo_text))


# ---------------------------------------------------------------------------
# model flops (the "useful work" yardstick)
# ---------------------------------------------------------------------------
def active_params(cfg: Any, params_proto: Any) -> Tuple[int, int]:
    """(total, active-per-token) parameter counts, embeddings excluded
    from the 6ND convention."""
    import jax
    total = 0
    active = 0
    flat = jax.tree_util.tree_flatten_with_path(params_proto)[0]
    for kp, leaf in flat:
        n = int(np.prod(leaf.shape))
        name = jax.tree_util.keystr(kp)
        total += n
        if "embed" in name or "head" in name and "['head']" in name:
            continue
        if "ffn" in name and ("w_gate" in name or "w_up" in name
                              or "w_down" in name):
            # routed experts: only top-k of E active
            if cfg.n_experts:
                active += n * cfg.n_experts_per_tok // cfg.n_experts
            else:
                active += n
        else:
            active += n
    return total, active


def model_flops(cfg: Any, params_proto: Any, kind: str, seq_len: int,
                global_batch: int) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (global)."""
    _, n_active = active_params(cfg, params_proto)
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    wire_bytes: float           # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_global: float
    useful_ratio: float         # model_flops / (hlo_flops * chips)
    roofline_frac: float        # max-term lower bound vs dominant
    n_collectives: int
    collectives_by_kind: Dict[str, float]
    memory_analysis: Dict[str, float]

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:28s} {self.shape:12s} {self.mesh:9s} "
                f"compute={self.compute_s*1e3:9.3f}ms "
                f"memory={self.memory_s*1e3:9.3f}ms "
                f"coll={self.collective_s*1e3:9.3f}ms "
                f"bound={self.bottleneck:10s} "
                f"useful={self.useful_ratio:6.3f} "
                f"frac={self.roofline_frac:5.3f}")


def _mem_dict(compiled: Any) -> Dict[str, float]:
    out: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return out
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = float(v)
    return out


def analyze_compiled(compiled: Any, *, arch: str, shape: str, mesh_name: str,
                     chips: int, cfg: Any = None,
                     params_proto: Any = None, kind: str = "train",
                     seq_len: int = 0, global_batch: int = 0
                     ) -> RooflineReport:
    from .hlo_walk import walk
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    totals = walk(hlo)
    # loop-aware dot flops (cost_analysis counts while bodies once);
    # keep the larger of the two so elementwise-dominated graphs are not
    # undercounted either.
    flops = max(totals.flops, float(cost.get("flops", 0.0)))
    # HBM bytes: loop-aware dot operand/result traffic vs cost_analysis's
    # single-pass "bytes accessed"
    nbytes = max(totals.dot_bytes, float(cost.get("bytes accessed", 0.0)))
    wire = totals.coll_wire
    by_kind: Dict[str, float] = dict(totals.coll_by_kind)
    n_coll = int(totals.n_coll)

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = wire / ICI_AXIS_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = (model_flops(cfg, params_proto, kind, seq_len, global_batch)
          if cfg is not None and params_proto is not None else 0.0)
    useful = mf / (flops * chips) if flops else 0.0
    # roofline fraction: time the dominant term says we need vs the sum —
    # a schedule that perfectly overlaps the other two terms achieves
    # max(terms)/sum(terms)=1; we report dominant/sum as the structural
    # overlap headroom, and the per-term seconds for iteration.
    tot = sum(terms.values())
    frac = terms[bottleneck] / tot if tot else 0.0

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, wire_bytes=wire,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops_global=mf,
        useful_ratio=useful, roofline_frac=frac,
        n_collectives=n_coll, collectives_by_kind=by_kind,
        memory_analysis=_mem_dict(compiled),
    )
