"""HLO-text walker: loop-aware flops / dot-bytes / collective accounting.

``compiled.cost_analysis()`` counts each ``while`` body ONCE (trip
counts are invisible to it), which silently undercounts scan-over-layers
models by ~n_layers×.  This walker parses the optimized HLO text,
computes per-computation dot-flops / dot-bytes / collective wire-bytes,
and multiplies through the call graph (fusion→calls, while→body×trip).

Trip counts come from the while condition computation: scans lower to a
`lt(counter, constant(N))` condition — we take the largest s32 constant
in the condition computation (exact for every lax.scan/lax.map loop this
framework emits).

Validated against analytically-known matmul/scan cases in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+)"
                     r"\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLL_KIND_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# operands print bare (`dot(%a, %b)`) on new XLA, typed
# (`dot(f32[128,128]{1,0} %a, ...)`) on older releases
_OPERAND = r"(?:[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?\s+)?%([\w.\-]+)"
_DOT_OPERANDS_RE = re.compile(
    r"\bdot\(\s*" + _OPERAND + r",\s*" + _OPERAND + r"\s*\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_of(tok_dt: str, tok_dims: str) -> Tuple[str, Tuple[int, ...]]:
    shape = tuple(int(x) for x in tok_dims.split(",") if x) \
        if tok_dims else ()
    return tok_dt, shape


def _nbytes(dt: str, shape: Tuple[int, ...]) -> float:
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = math.prod(shape) if shape else 1
    return float(n * _DTYPE_BYTES[dt])


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_wire: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_coll: int = 0
    whiles: List[Tuple[str, str]] = dataclasses.field(
        default_factory=list)          # (body, cond)
    calls: List[str] = dataclasses.field(default_factory=list)
    max_s32_const: int = 0


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        inner = m.group(1).strip()
        return len([t for t in inner.split(",") if t.strip() != ""])
    return 1


def _wire(kind: str, nbytes: float, g: int) -> float:
    if kind == "collective-permute":
        # cp has source_target_pairs, not replica_groups: full payload
        return float(nbytes)
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * nbytes * frac
    return nbytes * frac      # all-gather / reduce-scatter / all-to-all


def parse_computations(hlo: str) -> Tuple[Dict[str, CompStats],
                                          Dict[str, Tuple[str, Tuple]]]:
    """-> (per-computation stats, module-wide name -> (dtype, shape))."""
    comps: Dict[str, CompStats] = {}
    symbols: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    pending_dots: List[Tuple[CompStats, str]] = []
    cur: Optional[CompStats] = None

    for raw in hlo.splitlines():
        if raw and not raw[0].isspace():
            hdr = _COMP_HDR_RE.match(raw)
            if hdr and raw.rstrip().endswith("{") and "->" in raw:
                cur = comps.setdefault(hdr.group(1), CompStats())
                continue
        line = raw.strip()
        if cur is None or not line or line == "}":
            continue
        d = _DEF_RE.match(raw)
        if d:
            name, dt, dims = d.groups()
            symbols[name] = _shape_of(dt, dims)
        for cm in _CONST_RE.finditer(line):
            cur.max_s32_const = max(cur.max_s32_const, int(cm.group(1)))
        if re.search(r"\bdot\(", line):
            pending_dots.append((cur, line))
        cm = _COLL_KIND_RE.search(line)
        if cm and "-done" not in line.split("=")[0]:
            kind = cm.group(1)
            best = 0.0
            for sdt, sdims in _SHAPE_RE.findall(line):
                _, shp = _shape_of(sdt, sdims)
                best = max(best, _nbytes(sdt, shp))
            g = _group_size(line)
            wire = _wire(kind, best, g)
            cur.coll_wire += wire
            cur.coll_by_kind[kind] = cur.coll_by_kind.get(kind, 0.0) + wire
            cur.n_coll += 1
        if " while(" in line:
            b = re.search(r"body=%?([\w.\-]+)", line)
            c = re.search(r"condition=%?([\w.\-]+)", line)
            if b and c:
                cur.whiles.append((b.group(1), c.group(1)))
        else:
            for cm2 in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                   line):
                cur.calls.append(cm2.group(1))

    # resolve dots now that all symbols are known
    for comp, line in pending_dots:
        d = _DEF_RE.match("  " + line if not line.startswith(" ")
                          else line) or _DEF_RE.match(line)
        m_res = re.match(r"\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*"
                         r"([a-z0-9]+)\[([\d,]*)\]", line)
        if not m_res:
            continue
        rdt, rshape = _shape_of(*m_res.groups())
        ops = _DOT_OPERANDS_RE.search(line)
        cd = _CONTRACT_RE.search(line)
        k = 1.0
        op_bytes = 0.0
        if ops and cd:
            lhs = symbols.get(ops.group(1))
            rhs = symbols.get(ops.group(2))
            dims = [int(x) for x in cd.group(1).split(",") if x]
            if lhs:
                k = float(math.prod(lhs[1][i] for i in dims)) \
                    if dims else 1.0
                op_bytes += _nbytes(*lhs)
            if rhs:
                op_bytes += _nbytes(*rhs)
        relems = float(math.prod(rshape)) if rshape else 1.0
        comp.dot_flops += 2.0 * relems * k
        comp.dot_bytes += _nbytes(rdt, rshape) + op_bytes
    return comps, symbols


@dataclasses.dataclass
class WalkTotals:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_wire: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_coll: float = 0.0


def walk(hlo: str, entry: Optional[str] = None) -> WalkTotals:
    comps, _ = parse_computations(hlo)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    totals = WalkTotals()

    def visit(name: str, mult: float, depth: int = 0) -> None:
        if name not in comps or depth > 64:
            return
        c = comps[name]
        totals.flops += mult * c.dot_flops
        totals.dot_bytes += mult * c.dot_bytes
        totals.coll_wire += mult * c.coll_wire
        totals.n_coll += mult * c.n_coll
        for kind, v in c.coll_by_kind.items():
            totals.coll_by_kind[kind] = \
                totals.coll_by_kind.get(kind, 0.0) + mult * v
        for body, cond in c.whiles:
            trips = comps[cond].max_s32_const if cond in comps else 1
            visit(body, mult * max(trips, 1), depth + 1)
            visit(cond, mult * max(trips, 1), depth + 1)
        for callee in c.calls:
            visit(callee, mult, depth + 1)

    visit(entry, 1.0)
    return totals
