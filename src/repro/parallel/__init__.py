from .sharding import (active_mesh, constrain, dp_axes, ep_axis_name,
                       logical_spec, param_shardings, set_active_mesh,
                       set_rules, use_mesh, DEFAULT_RULES)
from . import pipeline  # noqa: F401

__all__ = [
    "active_mesh", "constrain", "dp_axes", "ep_axis_name", "logical_spec",
    "param_shardings", "set_active_mesh", "set_rules", "use_mesh",
    "DEFAULT_RULES", "pipeline",
]
