"""Pipeline parallelism as a first-class model execution mode.

The scan-over-layers stack is split across a ``pipe`` mesh axis (each
rank owns ``n_periods / pipe`` periods) and executed with the GPipe
microbatch schedule built on LCX send/recv
(`repro.parallel.pipeline.gpipe`).  The shard_map is *partial-manual*
(``axis_names={"pipe"}``): inside a stage, GSPMD still applies the
data/model sharding rules (FSDP × TP/SP), so PP composes with the rest
of the parallelism stack.

Autodiff through the GPipe schedule IS GPipe training (forward all
microbatches, backward in reverse — the ppermute transposes to the
opposite shift), so ``jax.grad`` of :func:`pp_loss` gives a
pipeline-parallel train step with no extra machinery.

Restrictions (asserted): no prefix layers, n_periods % pipe == 0, and
no shard_map-based MoE inside a stage (nested manual regions — use
``moe_backend="sort"`` configs for PP).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.common import softmax_xent
from repro.models.model import (_embed_in, _head_out, layer_apply)
from repro.parallel.pipeline import gpipe

PyTree = Any


def pp_apply_model(cfg: Any, params: PyTree, tokens: jax.Array, *,
                   mesh: Any, n_micro: int = 8,
                   impl: Optional[str] = None) -> jax.Array:
    """Pipeline-parallel forward.  tokens [B, S] -> logits [B, S, V]."""
    prefix, period, n_periods = cfg.scan_plan()
    assert not prefix, "PP demo requires a prefix-free layer plan"
    pipe = mesh.shape["pipe"]
    assert n_periods % pipe == 0, (n_periods, pipe)
    assert cfg.n_experts == 0 or cfg.moe_backend != "lcx", \
        "PP stages cannot nest the shard_map MoE; use moe_backend='sort'"

    x = _embed_in(cfg, params, tokens, None)
    b, s, d = x.shape
    assert b % n_micro == 0, (b, n_micro)
    positions = jnp.arange(s, dtype=jnp.int32)
    micro = x.reshape(n_micro, b // n_micro, s, d)

    def stage_fn(stack_local, xm):
        # stack_local leaves: [n_periods/pipe, ...] — this rank's periods
        def body(x_, p_period):
            for j, spec in enumerate(period):
                x_, _, _ = layer_apply(cfg, spec, p_period[f"l{j}"], x_,
                                       positions=positions, mode="train",
                                       impl=impl)
            return x_, None

        out, _ = lax.scan(body, xm, stack_local)
        return out

    def region(stack, micro_, rank_arr):
        # gpipe owns a private LCX runtime; no global init needed.
        # rank arrives as sharded data (each rank holds its own index)
        # because lax.axis_index lowers to PartitionId, which XLA CPU
        # SPMD partitioning rejects under partial-manual shard_map.
        # The region is fully manual; logical-axis constraints inside it
        # resolve to no-ops (sharding.py skips bound axes).
        return gpipe(stage_fn, stack, micro_, axis="pipe",
                     rank=rank_arr[0])

    from repro.compat import shard_map
    stack_spec = jax.tree.map(lambda _: P("pipe"), params["stack"])
    ranks = jnp.arange(pipe, dtype=jnp.int32)
    # Fully-manual shard_map: the pinned XLA release hard-aborts on
    # ppermute under partial-manual SPMD partitioning (and axis_index
    # lowers to PartitionId, which it rejects outright) — so the region
    # is manual on every mesh axis, with activations replicated across
    # non-pipe axes.  Partial-manual ({"pipe"} only) restores intra-stage
    # GSPMD once the toolchain moves past that bug.
    out_micro = shard_map(
        region, mesh=mesh, in_specs=(stack_spec, P(), P("pipe")),
        out_specs=P(), check=False)(params["stack"], micro, ranks)
    x = out_micro.reshape(b, s, d)
    return _head_out(cfg, params, x)


def pp_loss(cfg: Any, params: PyTree, batch: Dict[str, jax.Array], *,
            mesh: Any, n_micro: int = 8) -> jax.Array:
    logits = pp_apply_model(cfg, params, batch["tokens"], mesh=mesh,
                            n_micro=n_micro)
    return softmax_xent(logits, batch["labels"])
