"""Logical-axis sharding: names → mesh axes.

Every parameter/activation carries a tuple of *logical dimension names*
(see ``models/common.py``); this module resolves them to
``PartitionSpec``s against the active mesh using a rule table.

Rules are applied left-to-right per tensor with two safety filters:
- an axis already claimed by an earlier dim of the same tensor is
  skipped (GSPMD forbids reusing a mesh axis within one spec);
- an axis (or axis-tuple prefix) whose size does not divide the dim is
  skipped (keeps every arch/mesh combination compilable — e.g. 8 KV
  heads cannot shard 16-way, so they stay replicated).

The default rules implement **FSDP(ZeRO-3) × TP/EP**:
- ``embed`` (the contracting dim of most weights) shards over the data
  axes → every weight is fully sharded data×model;
- head/FFN/expert/vocab dims shard over ``model`` (TP / EP);
- ``batch`` shards over (pod, data).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import abstract_mesh

Rules = Dict[str, Tuple[str, ...]]

# logical dim -> preferred mesh axes (tried in order, prefix-divisible)
DEFAULT_RULES: Rules = {
    # activations.  The model axis carries SEQUENCE parallelism for
    # attention/SSM mixers (uniform across head counts — 14/36/64-head
    # archs cannot head-shard a 16-way axis) and TENSOR parallelism for
    # FFN/vocab; "attn_chunks" is the chunk-stack dim of the flash/SSD
    # block layout.
    "batch": ("pod", "data"),
    "seq": ("model",),
    "attn_chunks": ("model",),
    "vocab": ("model",),
    "q_heads": (),
    "ssm_act_heads": (),
    # params: FSDP on the embed/contracting dim, TP on the feature dim.
    # qkv/wo stay model-replicated (attention parallelism comes from the
    # sequence axis instead — see EXPERIMENTS.md §Perf iteration 1).
    "embed": ("data",),
    "embed_out": (),
    "mlp": ("model",),
    "q_proj": (),
    "kv_proj": (),
    "router": (),
    "experts": ("model",),
    "moe_mlp": (),
    "q_lora": ("model",),
    "kv_lora": (),
    "layers": (),                # scan-stacked leading dim
    # ssm
    "ssm_in": ("model",),
    "ssm_inner": ("model",),
    "ssm_conv_ch": ("model",),
    "ssm_heads": ("model",),
    "conv_k": (),
    "state": (),
    "head": (),
    # kv-cache
    "cache_batch": ("pod", "data"),
    "cache_seq": (),
    "kv_heads": ("model",),
}

_ACTIVE: Dict[str, Any] = {"mesh": None, "rules": dict(DEFAULT_RULES)}


def set_active_mesh(mesh: Optional[Mesh],
                    rules: Optional[Rules] = None) -> None:
    _ACTIVE["mesh"] = mesh
    if rules is not None:
        _ACTIVE["rules"] = {**DEFAULT_RULES, **rules}


def set_rules(rules: Rules) -> None:
    _ACTIVE["rules"] = {**DEFAULT_RULES, **rules}


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE["mesh"]


def active_rules() -> Rules:
    return _ACTIVE["rules"]


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[Rules] = None):
    prev = dict(_ACTIVE)
    set_active_mesh(mesh, rules)
    try:
        yield
    finally:
        _ACTIVE.update(prev)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def ep_axis_name() -> str:
    return "model"


# ---------------------------------------------------------------------------
# spec resolution
# ---------------------------------------------------------------------------
def _bound_axis_names() -> frozenset:
    """Mesh axes currently bound in the trace's axis env — i.e. manual
    inside a shard_map/vmap region.  A GSPMD constraint naming a manual
    axis is rejected (and must be: the data is already local), so rule
    resolution skips them."""
    try:
        from jax._src import core as _jcore
        return frozenset(_jcore.get_axis_env().axis_sizes)
    except Exception:  # pragma: no cover - jax-version drift
        return frozenset()


def _axes_for(dim: Optional[str], size: Optional[int], mesh: Mesh,
              used: set, rules: Rules) -> Optional[Tuple[str, ...]]:
    if dim is None:
        return None
    want = rules.get(dim, ())
    chosen = []
    prod = 1
    for ax in want:
        if ax not in mesh.shape or ax in used:
            continue
        nxt = prod * mesh.shape[ax]
        if size is not None and size % nxt != 0:
            break
        chosen.append(ax)
        prod = nxt
    if not chosen:
        return None
    used.update(chosen)
    return tuple(chosen)


def logical_spec(dims: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[Rules] = None) -> P:
    mesh = mesh or active_mesh()
    rules = rules or active_rules()
    if mesh is None:
        return P()
    used: set = set(_bound_axis_names())
    parts = []
    for i, d in enumerate(dims):
        size = None if shape is None else int(shape[i])
        axes = _axes_for(d, size, mesh, used, rules)
        parts.append(None if axes is None
                     else (axes[0] if len(axes) == 1 else axes))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint from logical dims (no-op without an
    active mesh — keeps CPU smoke tests mesh-free)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = logical_spec(dims, x.shape, mesh)
    if not spec:  # nothing shardable (e.g. every rule axis is manual)
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_shardings(dims_tree: Any, params_tree: Any = None,
                    mesh: Optional[Mesh] = None,
                    rules: Optional[Rules] = None) -> Any:
    """Map a dims tree (mirroring a params tree, leaves = tuples of
    logical names) to NamedShardings.  ``params_tree`` supplies shapes
    for divisibility checks (ShapeDtypeStructs work)."""
    mesh = mesh or active_mesh()
    is_leaf = lambda t: isinstance(t, tuple)  # noqa: E731

    if params_tree is None:
        return jax.tree.map(
            lambda d: NamedSharding(mesh, logical_spec(d, None, mesh, rules)),
            dims_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda d, p: NamedSharding(
            mesh, logical_spec(d, p.shape, mesh, rules)),
        dims_tree, params_tree, is_leaf=is_leaf)
