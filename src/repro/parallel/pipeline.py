"""Pipeline parallelism as an AMT task graph over LCX (GPipe schedule).

The paper's AMT communication pattern — many fine-grained asynchronous
point-to-point transfers with explicit completion — is exactly the
inter-stage traffic of a pipeline.  Here the GPipe schedule is built as
a :class:`repro.amt.TaskGraph`: every tick of the per-rank schedule is a
*task* (the stage × micro-batch cell this rank computes that tick), and
every inter-stage activation transfer is an *edge* realized as an LCX
``put`` whose completion resumes the suspended tick through the
executor's completion queue — no synchronizer polling in the schedule.

Run :func:`gpipe` under ``shard_map`` over the ``pipe`` axis; each rank
holds the parameters of its stage only (params sharded P('pipe', ...)
on the stacked leading dim).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size


def gpipe(stage_fn: Callable[[Any, jax.Array], jax.Array],
          stage_params: Any, microbatches: jax.Array, *,
          axis: str = "pipe", use_lcx: bool = True,
          runtime: Optional[Any] = None,
          device: Optional[Any] = None,
          rank: Optional[jax.Array] = None,
          failover: bool = False,
          heartbeat: Optional[Any] = None) -> jax.Array:
    """GPipe forward.  ``microbatches`` [M, mb, ...] (same value on every
    rank; only rank 0 injects).  Returns [M, mb, ...] outputs, valid on
    the *last* rank and broadcast to all ranks at the end.

    Schedule: M + n_stages - 1 ticks; rank r works on microbatch t - r at
    tick t (bubble ticks compute on garbage and are masked out).

    ``use_lcx=True`` drives the schedule through an AMT executor (tick
    tasks chained by LCX-put edges); ``use_lcx=False`` is the native
    ``lax.scan``/``ppermute`` reference schedule.

    ``rank`` overrides ``lax.axis_index(axis)`` as this rank's pipeline
    position — pass it where axis_index cannot lower (e.g. XLA CPU SPMD
    partitioning under partial-manual shard_map).

    ``failover=True`` (or an injected ``heartbeat`` monitor) provisions a
    warm standby device on the pipe axis and attaches a
    ``HeartbeatMonitor(on_dead="failover")`` to the pipeline runtime: a
    stage device declared dead mid-schedule migrates its endpoints and
    in-flight activation transfers onto the standby, and the executor
    re-dispatches the affected tick tasks (``docs/faults.md``).
    """
    if not use_lcx:
        return _gpipe_native(stage_fn, stage_params, microbatches,
                             axis=axis, rank=rank)
    return _gpipe_taskgraph(stage_fn, stage_params, microbatches,
                            axis=axis, runtime=runtime, device=device,
                            rank=rank, failover=failover,
                            heartbeat=heartbeat)


def _gpipe_taskgraph(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any, microbatches: jax.Array, *,
                     axis: str, runtime: Optional[Any] = None,
                     device: Optional[Any] = None,
                     rank: Optional[jax.Array] = None,
                     failover: bool = False,
                     heartbeat: Optional[Any] = None) -> jax.Array:
    import repro.core as lcx
    from repro.amt import Executor

    n = axis_size(axis)
    idx = rank if rank is not None else lax.axis_index(axis)
    M = microbatches.shape[0]
    mb_shape = microbatches.shape[1:]

    # Library-interop pattern: the pipeline owns a private runtime and an
    # isolated device on the pipe axis unless the caller injects theirs —
    # inter-stage traffic never routes through the global default runtime.
    if runtime is None:
        runtime = device.runtime if device is not None else None
    if runtime is None:
        runtime = lcx.Runtime(name="gpipe")
    dev = device if device is not None else runtime.device(axis=axis)
    if failover or heartbeat is not None:
        from repro.runtime.fault import HeartbeatMonitor
        # Warm standby on the same axis: the migration target when the
        # heartbeat declares a stage device dead mid-schedule.
        runtime.device(axis=axis)
        if heartbeat is None:
            heartbeat = HeartbeatMonitor(on_dead="failover")
        heartbeat.attach(runtime)
    ex = Executor(device=dev, runtime=runtime, name="gpipe")
    # Mutable per-rank cells the tick tasks thread state through: the
    # activation arriving from the predecessor stage, and the output
    # accumulator (valid rows written by the last stage only).
    cells = {
        "incoming": jnp.zeros(mb_shape, microbatches.dtype),
        "outputs": jnp.zeros((M,) + mb_shape, microbatches.dtype),
    }

    def make_tick(t: int):
        def tick(ctx):
            mb_idx = min(t, M - 1)
            first = microbatches[mb_idx]
            x_in = jnp.where(idx == 0, first, cells["incoming"])
            y = stage_fn(stage_params, x_in)
            if t >= n - 1:
                out_idx = min(t - (n - 1), M - 1)
                cur = cells["outputs"][out_idx]
                cells["outputs"] = cells["outputs"].at[out_idx].set(
                    jnp.where(idx == n - 1, y, cur))
            # Edge to the next tick: put the activation to the successor
            # stage and suspend until the predecessor's put lands here.
            ctx.put(y, lcx.Perm.shift(1))
            return ctx.suspend(
                lambda ev: cells.__setitem__("incoming", ev.payload))

        return tick

    prev = None
    for t in range(M + n - 1):
        prev = ex.spawn(make_tick(t), deps=(prev,) if prev else (),
                        priority=-t, name=f"tick{t}")
    ex.run()

    # broadcast final outputs from the last stage to every rank
    outputs = cells["outputs"]
    mask = (idx == n - 1).astype(outputs.dtype)
    return lax.psum(outputs * mask, axis)


def _gpipe_native(stage_fn: Callable[[Any, jax.Array], jax.Array],
                  stage_params: Any, microbatches: jax.Array, *,
                  axis: str,
                  rank: Optional[jax.Array] = None) -> jax.Array:
    """Reference schedule: one ``lax.scan`` over ticks, shifts via raw
    ``ppermute`` (no LCX, no executor)."""
    n = axis_size(axis)
    idx = rank if rank is not None else lax.axis_index(axis)
    M = microbatches.shape[0]
    mb_shape = microbatches.shape[1:]

    def tick(carry, t):
        incoming, outputs = carry
        mb_idx = jnp.clip(t, 0, M - 1)
        first = lax.dynamic_index_in_dim(microbatches, mb_idx, 0,
                                         keepdims=False)
        x_in = jnp.where(idx == 0, first, incoming)
        y = stage_fn(stage_params, x_in)
        out_idx = jnp.clip(t - (n - 1), 0, M - 1)
        valid = (t >= n - 1) & (idx == n - 1)
        cur = lax.dynamic_index_in_dim(outputs, out_idx, 0, keepdims=False)
        outputs = lax.dynamic_update_index_in_dim(
            outputs, jnp.where(valid, y, cur), out_idx, 0)
        incoming = lax.ppermute(y, axis,
                                [(i, (i + 1) % n) for i in range(n)])
        return (incoming, outputs), None

    outputs0 = jnp.zeros((M,) + mb_shape, microbatches.dtype)
    incoming0 = jnp.zeros(mb_shape, microbatches.dtype)
    (_, outputs), _ = lax.scan(tick, (incoming0, outputs0),
                               jnp.arange(M + n - 1))
    mask = (idx == n - 1).astype(outputs.dtype)
    return lax.psum(outputs * mask, axis)


def stage_slice(params_stacked: Any, axis: str = "pipe") -> Any:
    """Inside shard_map with params in_spec P('pipe', ...), each rank
    already holds [1, ...]; drop the leading dim."""
    return jax.tree.map(lambda t: t[0], params_stacked)
