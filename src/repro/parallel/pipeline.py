"""Pipeline parallelism built on LCX send/recv (GPipe schedule).

The paper's AMT communication pattern — many fine-grained asynchronous
point-to-point transfers with explicit completion — is exactly the
inter-stage traffic of a pipeline.  Each tick, every stage posts an LCX
``put`` of its activation to the successor, calls ``progress()`` (the
overlap point), and waits on a synchronizer.

Run :func:`gpipe` under ``shard_map`` over the ``pipe`` axis; each rank
holds the parameters of its stage only (params sharded P('pipe', ...)
on the stacked leading dim).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax


def gpipe(stage_fn: Callable[[Any, jax.Array], jax.Array],
          stage_params: Any, microbatches: jax.Array, *,
          axis: str = "pipe", use_lcx: bool = True) -> jax.Array:
    """GPipe forward.  ``microbatches`` [M, mb, ...] (same value on every
    rank; only rank 0 injects).  Returns [M, mb, ...] outputs, valid on
    the *last* rank and broadcast to all ranks at the end.

    Schedule: M + n_stages - 1 ticks; rank r works on microbatch t - r at
    tick t (bubble ticks compute on garbage and are masked out).
    """
    import repro.core as lcx

    n = lax.axis_size(axis)
    idx = lax.axis_index(axis)
    M = microbatches.shape[0]
    mb_shape = microbatches.shape[1:]
    dev = lcx.Device(axis=axis) if use_lcx else None

    def shift_next(y: jax.Array) -> jax.Array:
        if use_lcx:
            sync = lcx.Synchronizer(threshold=1)
            lcx.put_x(y).perm(lcx.Perm.shift(1)).remote_comp(sync) \
                .device(dev)()
            lcx.progress_x().device(dev)()
            (ev,) = sync.wait()
            return ev.payload
        return lax.ppermute(y, axis, [(i, (i + 1) % n) for i in range(n)])

    def tick(carry, t):
        incoming, outputs = carry
        mb_idx = jnp.clip(t, 0, M - 1)
        first = lax.dynamic_index_in_dim(microbatches, mb_idx, 0,
                                         keepdims=False)
        x_in = jnp.where(idx == 0, first, incoming)
        y = stage_fn(stage_params, x_in)
        out_idx = jnp.clip(t - (n - 1), 0, M - 1)
        valid = (t >= n - 1) & (idx == n - 1)
        cur = lax.dynamic_index_in_dim(outputs, out_idx, 0, keepdims=False)
        outputs = lax.dynamic_update_index_in_dim(
            outputs, jnp.where(valid, y, cur), out_idx, 0)
        incoming = shift_next(y)
        return (incoming, outputs), None

    outputs0 = jnp.zeros((M,) + mb_shape, microbatches.dtype)
    incoming0 = jnp.zeros(mb_shape, microbatches.dtype)
    (_, outputs), _ = lax.scan(tick, (incoming0, outputs0),
                               jnp.arange(M + n - 1))
    # broadcast final outputs from the last stage to every rank
    mask = (idx == n - 1).astype(outputs.dtype)
    return lax.psum(outputs * mask, axis)


def stage_slice(params_stacked: Any, axis: str = "pipe") -> Any:
    """Inside shard_map with params in_spec P('pipe', ...), each rank
    already holds [1, ...]; drop the leading dim."""
    return jax.tree.map(lambda t: t[0], params_stacked)
