"""Serving driver: continuous-batching engine over a smoke-size model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 16 --max-new 24
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models import init_model
from repro.serving import Request, ServeConfig, ServingEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--full", action="store_true",
                   help="full config (requires a real cluster)")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=256)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("encoder-only architectures have no decode path")
    params, _ = init_model(jax.random.PRNGKey(args.seed), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(
        n_slots=args.slots, max_seq=args.max_seq,
        max_new_tokens=args.max_new, temperature=args.temperature,
        seed=args.seed))
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab, size=plen).astype(np.int32)))
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s); stats={eng.stats}")
    for r in done[:4]:
        print(f"  rid={r.rid} prompt={list(r.prompt)[:6]}... "
              f"output={r.output}")


if __name__ == "__main__":
    main()
