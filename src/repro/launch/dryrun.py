import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, print memory/cost analysis, and emit the
roofline terms (EXPERIMENTS.md §Dry-run / §Roofline read from here).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all \
        --out results/dryrun.jsonl
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.analysis.roofline import analyze_compiled
from repro.configs.base import SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import arch_rules, build_bundle
from repro.models import abstract_init
from repro.parallel.sharding import set_active_mesh, use_mesh


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             verbose: bool = True,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    info = SHAPES[shape]
    kind = info["kind"]
    rules = arch_rules(cfg, mesh, kind)

    t0 = time.perf_counter()
    with use_mesh(mesh, rules):
        bundle = build_bundle(cfg, mesh, kind, info["seq_len"],
                              info["global_batch"])
        lowered = bundle.fn.lower(*bundle.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        params_proto, _ = abstract_init(cfg)
        report = analyze_compiled(
            compiled, arch=arch, shape=shape, mesh_name=mesh_name,
            chips=chips, cfg=cfg, params_proto=params_proto, kind=kind,
            seq_len=info["seq_len"], global_batch=info["global_batch"])
    rec = report.to_dict()
    rec.update({"t_lower_s": t_lower, "t_compile_s": t_compile,
                "kind": kind, "ok": True})
    if verbose:
        mem = rec["memory_analysis"]
        print(report.summary())
        print(f"    lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"out={mem.get('output_size_in_bytes', 0)/2**30:.2f}GiB "
              f"ncoll={rec['n_collectives']} "
              f"by_kind={ {k: round(v/2**20, 1) for k, v in rec['collectives_by_kind'].items()} }MiB")
        sys.stdout.flush()
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default=None)
    p.add_argument("--override", action="append", default=[],
                   help="cfg override key=value (python literal)")
    args = p.parse_args()

    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        import ast
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    todo = []
    if args.all:
        for arch, shape in cells():
            todo.append((arch, shape, False))
            todo.append((arch, shape, True))
    else:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    out_f = open(args.out, "a") if args.out else None
    n_ok = 0
    for arch, shape, mp in todo:
        try:
            rec = run_cell(arch, shape, multi_pod=mp,
                           overrides=overrides or None)
            n_ok += 1
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"FAIL {arch} {shape} mp={mp}: {rec['error']}")
            traceback.print_exc()
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
            out_f.flush()
    if out_f:
        out_f.close()
    print(f"dry-run complete: {n_ok}/{len(todo)} cells OK")
    if n_ok < len(todo):
        sys.exit(1)


if __name__ == "__main__":
    main()
