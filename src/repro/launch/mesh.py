"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on CPU placeholder devices.

Single pod:  (data=16, model=16)            = 256 chips (one v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Axis roles (see repro.parallel.sharding.DEFAULT_RULES):
- ``pod``   — data parallelism across pods (gradient reduction crosses
  the inter-pod links; the compressed-allreduce path targets this axis)
- ``data``  — data parallelism + FSDP(ZeRO-3) parameter sharding
- ``model`` — tensor parallelism / expert parallelism / sequence
  parallelism for long-context decode
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return _compat_make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Optional[Mesh]:
    """Best-effort mesh from whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    if n == 1:
        return None
    model = model or (2 if n % 2 == 0 else 1)
    data = n // model
    return make_mesh((data, model), ("data", "model"))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_LINK_BW = 50e9                # B/s per link per direction
ICI_AXIS_BW = 2 * ICI_LINK_BW     # ring uses both directions of an axis
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip
