"""Step builders shared by the dry-run, the trainer, and the server:
per (arch × shape-kind), the jitted function plus ShapeDtypeStruct input
prototypes and NamedShardings for every argument.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data import batch_specs
from repro.models import abstract_init, decode_step, init_cache, prefill
from repro.models.model import cache_batch_axes
from repro.optim import AdamWState, adamw_init, cosine_schedule
from repro.parallel.sharding import (active_rules, logical_spec,
                                     param_shardings)
from repro.runtime.trainer import TrainConfig, make_train_step

PyTree = Any


def arch_rules(cfg: Any, mesh: Mesh, kind: str
               ) -> Dict[str, Tuple[str, ...]]:
    """Per-arch sharding-rule overrides.

    Head-TP archs (n_kv_heads divides the model axis — MLA's 128 heads,
    hubert's 16): restore Megatron column-parallel qkv / row-parallel wo
    weight sharding so q/k/v come out head-sharded locally (§Perf
    iteration 2b — avoids resharding multi-GiB q/k/v between the
    sequence and head layouts every layer).  Chunk-mode archs keep
    qkv/wo model-replicated (sequence parallelism carries attention).
    """
    rules: Dict[str, Tuple[str, ...]] = {}
    tp = mesh.shape.get("model", 1)
    if tp > 1 and cfg.n_kv_heads and cfg.n_kv_heads % tp == 0:
        rules.update({"q_proj": ("model",), "kv_proj": ("model",)})
    if kind == "decode":
        rules.update(decode_rules(cfg, mesh))
    return rules


def decode_rules(cfg: Any, mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    """Sharding-rule overrides for decode cells.

    KV caches shard over the model axis by heads when divisible;
    otherwise (and always for MLA's head-less latent cache) by sequence
    — context-parallel decode, GSPMD inserts the partial-softmax
    collectives."""
    tp = mesh.shape.get("model", 1)
    rules: Dict[str, Tuple[str, ...]] = {}
    if cfg.n_experts:
        # resident-expert decode: experts shard over the joint
        # (data..., model) axes so the FFN weights never stream
        from repro.models.moe import resident_plan
        axes = resident_plan(cfg, mesh)
        if axes is not None:
            rules["experts"] = axes
    if cfg.n_kv_heads and cfg.n_kv_heads % tp == 0 \
            and not cfg.kv_lora_rank:
        return rules
    rules.update({"cache_seq": ("model",), "kv_heads": ()})
    return rules


def cache_dims(cfg: Any, caches: PyTree) -> PyTree:
    """Logical-dims tree mirroring ``init_cache`` output."""
    from repro.models.attention import attn_cache_dims
    from repro.models.mla import mla_cache_dims
    from repro.models.ssm import ssm_cache_dims
    prefix, period, _ = cfg.scan_plan()

    def dims_for(spec):
        if spec.mixer == "attn":
            return attn_cache_dims()
        if spec.mixer == "mla":
            return mla_cache_dims()
        return ssm_cache_dims()

    out: Dict[str, Any] = {}
    for i, spec in enumerate(prefix):
        out[f"prefix_{i}"] = dims_for(spec)
    stack: Dict[str, Any] = {}
    for j, spec in enumerate(period):
        stack[f"l{j}"] = jax.tree.map(
            lambda d: ("layers",) + d, dims_for(spec),
            is_leaf=lambda t: isinstance(t, tuple))
    out["stack"] = stack
    return out


@dataclasses.dataclass
class StepBundle:
    fn: Any                       # jitted function
    args: Tuple[Any, ...]         # ShapeDtypeStruct prototypes
    donate: Tuple[int, ...] = ()


def _sds(tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_train_bundle(cfg: Any, mesh: Mesh, seq_len: int,
                       global_batch: int,
                       kernels: Optional[Dict[str, Any]] = None,
                       tcfg: Optional[TrainConfig] = None) -> StepBundle:
    tcfg = tcfg or TrainConfig(seq_len=seq_len, global_batch=global_batch)
    params_proto, dims = abstract_init(cfg)
    pshard = param_shardings(dims, params_proto, mesh)
    opt_proto = jax.eval_shape(
        lambda p: adamw_init(p, cfg.opt_dtype), params_proto)
    opt_shard = AdamWState(step=NamedSharding(mesh, P()),
                           m=pshard, v=pshard)
    bspecs = batch_specs(cfg, seq_len, global_batch)
    bshard = {
        k: NamedSharding(mesh, logical_spec(
            ("batch",) + (None,) * (len(v.shape) - 1), v.shape, mesh))
        for k, v in bspecs.items()}
    lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)
    step = make_train_step(cfg, tcfg, lr_fn, kernels)
    jitted = jax.jit(
        step,
        in_shardings=(pshard, opt_shard, bshard),
        out_shardings=(pshard, opt_shard, None),
        donate_argnums=(0, 1),
    )
    return StepBundle(fn=jitted, args=(params_proto, opt_proto, bspecs),
                      donate=(0, 1))


def build_prefill_bundle(cfg: Any, mesh: Mesh, seq_len: int,
                         global_batch: int,
                         kernels: Optional[Dict[str, Any]] = None
                         ) -> StepBundle:
    params_proto, dims = abstract_init(cfg)
    pshard = param_shardings(dims, params_proto, mesh)
    tok_proto = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    tok_shard = NamedSharding(mesh, logical_spec(
        ("batch", None), tok_proto.shape, mesh))

    if cfg.family == "audio":
        # encoder-only: "prefill" is the full forward over frame
        # embeddings (the modality frontend stub) — no KV cache exists
        from repro.models import apply_model
        fe_proto = jax.ShapeDtypeStruct(
            (global_batch, seq_len, cfg.d_model), cfg.dtype)
        fe_shard = NamedSharding(mesh, logical_spec(
            ("batch", "seq", None), fe_proto.shape, mesh))

        def astep(params, tokens, frontend):
            logits, _ = apply_model(cfg, params, tokens,
                                    frontend_embeds=frontend,
                                    kernels=kernels)
            return logits

        jitted = jax.jit(astep,
                         in_shardings=(pshard, tok_shard, fe_shard),
                         out_shardings=None)
        return StepBundle(fn=jitted,
                          args=(params_proto, tok_proto, fe_proto))

    fe_len = cfg.frontend_len if cfg.family == "vlm" else 0
    caches_proto = jax.eval_shape(
        lambda: init_cache(cfg, global_batch, seq_len + fe_len))
    cshard = param_shardings(cache_dims(cfg, caches_proto), caches_proto,
                             mesh)
    fe_proto = None
    if fe_len:
        fe_proto = jax.ShapeDtypeStruct(
            (global_batch, fe_len, cfg.d_model), cfg.dtype)
        fe_shard = NamedSharding(mesh, logical_spec(
            ("batch", None, None), fe_proto.shape, mesh))

        def vstep(params, tokens, frontend, caches):
            return prefill(cfg, params, tokens, caches,
                           frontend_embeds=frontend, kernels=kernels)

        jitted = jax.jit(vstep,
                         in_shardings=(pshard, tok_shard, fe_shard,
                                       cshard),
                         out_shardings=(None, cshard),
                         donate_argnums=(3,))
        return StepBundle(fn=jitted,
                          args=(params_proto, tok_proto, fe_proto,
                                caches_proto), donate=(3,))

    def step(params, tokens, caches):
        return prefill(cfg, params, tokens, caches, kernels=kernels)

    jitted = jax.jit(step,
                     in_shardings=(pshard, tok_shard, cshard),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))
    return StepBundle(fn=jitted,
                      args=(params_proto, tok_proto, caches_proto),
                      donate=(2,))


def build_decode_bundle(cfg: Any, mesh: Mesh, seq_len: int,
                        global_batch: int,
                        kernels: Optional[Dict[str, Any]] = None
                        ) -> StepBundle:
    """One decode step with a KV cache of ``seq_len`` tokens."""
    params_proto, dims = abstract_init(cfg)
    pshard = param_shardings(dims, params_proto, mesh)
    caches_proto = jax.eval_shape(
        lambda: init_cache(cfg, global_batch, seq_len))
    cshard = param_shardings(cache_dims(cfg, caches_proto), caches_proto,
                             mesh)
    tok_proto = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    tok_shard = NamedSharding(mesh, logical_spec(
        ("batch", None), tok_proto.shape, mesh))
    len_proto = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, tokens, caches, length):
        return decode_step(cfg, params, tokens, caches, length,
                           kernels=kernels)

    jitted = jax.jit(step,
                     in_shardings=(pshard, tok_shard, cshard,
                                   NamedSharding(mesh, P())),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))
    return StepBundle(fn=jitted,
                      args=(params_proto, tok_proto, caches_proto,
                            len_proto),
                      donate=(2,))


def build_bundle(cfg: Any, mesh: Mesh, kind: str, seq_len: int,
                 global_batch: int,
                 kernels: Optional[Dict[str, Any]] = None) -> StepBundle:
    if kind == "train":
        return build_train_bundle(cfg, mesh, seq_len, global_batch,
                                  kernels)
    if kind == "prefill":
        return build_prefill_bundle(cfg, mesh, seq_len, global_batch,
                                    kernels)
    if kind == "decode":
        return build_decode_bundle(cfg, mesh, seq_len, global_batch,
                                   kernels)
    raise ValueError(f"unknown step kind {kind!r}")
