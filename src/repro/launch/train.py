"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 200 --seq-len 256 --batch 16 --ckpt-dir /tmp/ck

``--smoke`` uses the arch's reduced config (CPU-runnable); without it
the full config is built (requires a real cluster).  The trainer wires
checkpoint/restart, failure recovery, straggler monitoring and elastic
remesh (see repro.runtime).
"""
import argparse
import json

import jax

from repro.configs.base import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.runtime import FailureInjector, TrainConfig, Trainer


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--grad-accum", type=int, default=1)
    p.add_argument("--compressed-accum", action="store_true")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--inject-failure-at", type=int, action="append",
                   default=[])
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    tcfg = TrainConfig(
        lr=args.lr, warmup=args.warmup, total_steps=args.steps,
        seq_len=args.seq_len, global_batch=args.batch,
        grad_accum=args.grad_accum,
        compressed_accum=args.compressed_accum,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        seed=args.seed,
    )
    mesh = make_host_mesh()
    injector = FailureInjector(fail_at=args.inject_failure_at) \
        if args.inject_failure_at else None
    trainer = Trainer(cfg, tcfg, mesh=mesh, failure_injector=injector)
    if args.resume:
        restored = trainer.restore()
        print(f"resume: {'ok, step ' + str(trainer.step_count) if restored else 'no checkpoint found'}")
    result = trainer.run(args.steps)
    print(json.dumps(result, indent=2, default=str))
    for m in trainer.metrics_log:
        print(f"step {m['step']:5d} loss={m['loss']:.4f} "
              f"lr={m['lr']:.2e} dt={m['dt']*1e3:.0f}ms {m['straggler']}")


if __name__ == "__main__":
    main()
