"""deepseek-v3-671b  [moe]  61L d_model=7168 128H (MLA) moe_d_ff=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].

First 3 layers dense (d_ff=18432); sigmoid router with top-k
normalization; MLA: q_lora 1536 / kv_lora 512 / nope 128 / rope 64 /
v_head 128.  Optimizer moments in bf16 (fits 256 chips; see
EXPERIMENTS.md §Dry-run memory table).
"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("deepseek-v3-671b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab=129280, norm="rms", act="swiglu",
        first_k_dense=3,
        n_experts=256, n_experts_per_tok=8, moe_d_ff=2048,
        n_shared_experts=1, router_type="sigmoid", router_norm_topk=True,
        moe_backend="lcx", capacity_factor=1.25,
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        mtp_depth=1, mtp_loss_coef=0.3,
        opt_dtype=jnp.bfloat16,
        max_seq_len=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab=128, first_k_dense=1,
        n_experts=8, n_experts_per_tok=2, moe_d_ff=64,
        n_shared_experts=1, router_type="sigmoid",
        moe_backend="sort", capacity_factor=4.0,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16, mtp_depth=1,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
