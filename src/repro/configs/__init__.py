from .base import (ARCH_IDS, SHAPES, LayerSpec, ModelConfig, cells,
                   get_config, get_smoke_config, list_archs, register)

__all__ = ["ARCH_IDS", "SHAPES", "LayerSpec", "ModelConfig", "cells",
           "get_config", "get_smoke_config", "list_archs", "register"]
