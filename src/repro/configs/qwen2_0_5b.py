"""qwen2-0.5b  [dense]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias  [arXiv:2407.10671; hf]"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("qwen2-0.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
        vocab=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6, norm="rms", act="swiglu",
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=128, qkv_bias=True, tie_embeddings=True,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
