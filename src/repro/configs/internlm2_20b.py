"""internlm2-20b  [dense]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA  [arXiv:2403.17297; hf]"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("internlm2-20b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
        vocab=92544, norm="rms", act="swiglu", rope_theta=1e6,
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab=128, dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
