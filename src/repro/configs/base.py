"""Config schema for all architectures and input-shape cells.

One ``<arch>.py`` per assigned architecture instantiates
:class:`ModelConfig`; :func:`get_config` resolves by id; each config also
provides a ``smoke()`` reduction for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str          # "attn" | "mla" | "mamba"
    ffn: Optional[str]  # "dense" | "moe" | None


@dataclasses.dataclass
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"     # dense|moe|ssm|hybrid|encoder|vlm|audio

    # trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None      # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    norm: str = "rms"
    norm_eps: float = 1e-6
    act: str = "swiglu"                 # swiglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False               # qwen3-style
    rope_theta: float = 10000.0
    causal: bool = True                 # False for encoder-only
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    max_seq_len: int = 8192

    # layer plan
    first_k_dense: int = 0              # prefix of plain dense layers
    attn_layer_period: int = 1          # hybrid: attention every k layers
    attn_layer_offset: int = 0
    expert_layer_period: int = 1        # MoE every k layers
    expert_layer_offset: int = 0
    scan_period: Optional[int] = None   # layers per scan step (auto)

    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    router_type: str = "softmax"        # softmax | sigmoid (dsv3)
    router_norm_topk: bool = True
    capacity_factor: float = 1.25
    moe_backend: str = "lcx"            # lcx (shard_map a2a) | dense
    moe_a2a: str = "native"             # LCX a2a lowering: native|pairwise
    aux_loss_coef: float = 0.001

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # multi-token prediction (deepseek v3)
    mtp_depth: int = 0
    mtp_loss_coef: float = 0.3

    # modality frontend stub ([audio]/[vlm]): input_specs provides
    # precomputed frame/patch embeddings of this length (prepended).
    frontend: Optional[str] = None      # None | "audio" | "vision"
    frontend_len: int = 0

    # numerics / memory
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    opt_dtype: Any = jnp.float32        # adam moments
    remat: str = "full"                 # none | full | dots
    # query-block size for chunked attention.  Also sets the chunk count
    # S/q_block — the sequence-parallel shard dim, so S/q_block must be
    # a multiple of the model-axis size for the chunk sharding to bite.
    q_block: int = 256
    grad_accum: int = 1

    # parallelism hints (logical->mesh rules live in parallel/sharding.py)
    use_flash_kernel: bool = False      # Pallas path (TPU only)

    # ------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.head_dim is None:
            self.head_dim = self.d_model // max(self.n_heads, 1)

    # -- layer plan -----------------------------------------------------
    def layer_plan(self) -> List[LayerSpec]:
        plan: List[LayerSpec] = []
        for i in range(self.n_layers):
            if self.family in ("ssm",):
                plan.append(LayerSpec("mamba", None))
                continue
            if self.family == "hybrid":
                mixer = ("attn" if i % self.attn_layer_period ==
                         self.attn_layer_offset else "mamba")
            elif self.q_lora_rank or self.kv_lora_rank:
                mixer = "mla"
            else:
                mixer = "attn"
            if i < self.first_k_dense or self.n_experts == 0:
                ffn = "dense"
            elif i % self.expert_layer_period == self.expert_layer_offset:
                ffn = "moe"
            else:
                ffn = "dense"
            plan.append(LayerSpec(mixer, ffn))
        return plan

    def scan_plan(self) -> Tuple[List[LayerSpec], List[LayerSpec], int]:
        """Split the plan into (prefix, period_body, n_periods) so the body
        repeats exactly — the scan-over-layers shape."""
        plan = self.layer_plan()
        prefix = plan[: self.first_k_dense]
        rest = plan[self.first_k_dense:]
        period = self.scan_period
        if period is None:
            # smallest p such that rest is p-periodic
            for p in range(1, len(rest) + 1):
                if len(rest) % p == 0 and all(
                        rest[i] == rest[i % p] for i in range(len(rest))):
                    period = p
                    break
        assert period is not None and len(rest) % period == 0, (
            self.name, period, len(rest))
        return prefix, rest[:period], len(rest) // period

    # -- derived sizes ----------------------------------------------------
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def kv_cache_spec(self, batch: int, seq: int) -> Dict[str, Any]:
        """Logical description of the decode cache (see serving/)."""
        return {"batch": batch, "seq": seq}


# registry ------------------------------------------------------------------
_REGISTRY: Dict[str, Any] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import importlib
    if name not in _REGISTRY:
        importlib.import_module(
            f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    import importlib
    mod_name = f"repro.configs.{name.replace('-', '_').replace('.', '_')}"
    mod = importlib.import_module(mod_name)
    return mod.smoke()


def list_archs() -> List[str]:
    return sorted(ARCH_IDS)


ARCH_IDS = [
    "jamba-1.5-large-398b",
    "qwen2-0.5b",
    "command-r-plus-104b",
    "internlm2-20b",
    "starcoder2-7b",
    "hubert-xlarge",
    "mamba2-130m",
    "deepseek-v3-671b",
    "qwen3-moe-30b-a3b",
    "llava-next-mistral-7b",
]

# input-shape cells (LM family): seq_len x global_batch ---------------------
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# Shape-cell applicability (skips recorded in DESIGN.md §5):
#  - long_500k only for sub-quadratic archs (ssm/hybrid decode)
#  - decode shapes skipped for encoder-only archs
LONG_OK = {"mamba2-130m", "jamba-1.5-large-398b"}
ENCODER_ONLY = {"hubert-xlarge"}


def cells() -> List[Tuple[str, str]]:
    out: List[Tuple[str, str]] = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            if SHAPES[shape]["kind"] == "decode" and arch in ENCODER_ONLY:
                continue
            out.append((arch, shape))
    return out
