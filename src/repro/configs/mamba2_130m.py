"""mamba2-130m  [ssm]  24L d_model=768 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060;
unverified]"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("mamba2-130m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
        ssm_groups=1, ssm_conv=4, ssm_chunk=256,
        tie_embeddings=True, norm="rms",
        max_seq_len=1048576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=128, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        tie_embeddings=True,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
