"""command-r-plus-104b  [dense]  64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("command-r-plus-104b")
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
        vocab=256000, qkv_bias=False, norm="layer", act="swiglu",
        rope_theta=75e6, tie_embeddings=True,   # cohere ties embeddings
        max_seq_len=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab=128, norm="layer", tie_embeddings=True,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
