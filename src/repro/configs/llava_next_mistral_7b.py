"""llava-next-mistral-7b  [vlm]  32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000 — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone = Mistral-7B.  The vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings
[B, frontend_len, d_model] (anyres base grid 24x24 = 576 patches),
prepended to the token sequence.
"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("llava-next-mistral-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab=32000, norm="rms", act="swiglu", rope_theta=1e6,
        frontend="vision", frontend_len=576,
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=128, frontend="vision", frontend_len=16,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
