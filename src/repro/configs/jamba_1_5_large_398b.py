"""jamba-1.5-large-398b  [hybrid]  72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

Jamba period: 8 layers with attention at offset 4 (1 attn : 7 mamba),
MoE every other layer (offset 1).  The Mamba layers use our Mamba-2/SSD
block (hardware adaptation recorded in DESIGN.md — the SSD form is the
TPU-friendly formulation of the same SSM).
"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("jamba-1.5-large-398b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
        vocab=65536, norm="rms", act="swiglu",
        attn_layer_period=8, attn_layer_offset=4,
        n_experts=16, n_experts_per_tok=2, moe_d_ff=24576,
        expert_layer_period=2, expert_layer_offset=1,
        moe_backend="lcx", capacity_factor=1.25,
        ssm_state=128, ssm_expand=2, ssm_head_dim=128, ssm_groups=8,
        ssm_conv=4, ssm_chunk=256,
        max_seq_len=1048576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=128, attn_layer_period=8, attn_layer_offset=4,
        n_experts=4, n_experts_per_tok=2, moe_d_ff=160,
        expert_layer_period=2, expert_layer_offset=1,
        moe_backend="sort", capacity_factor=4.0,
        ssm_state=16, ssm_head_dim=16, ssm_groups=2, ssm_chunk=16,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
