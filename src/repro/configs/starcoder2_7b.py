"""starcoder2-7b  [dense]  32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE  [arXiv:2402.19173; hf]"""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("starcoder2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
        vocab=49152, qkv_bias=True, norm="layer", act="gelu",
        rope_theta=1e5, sliding_window=4096,
        max_seq_len=16384,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab=128, qkv_bias=True, norm="layer", act="gelu",
        sliding_window=16,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
