"""qwen3-moe-30b-a3b  [moe]  48L d_model=2048 32H (GQA kv=4)
moe_d_ff=768 vocab=151936, MoE 128e top-8 — 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B; hf].  head_dim=128 (decoupled from d_model);
QK-norm per qwen3."""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        head_dim=128, d_ff=6144, vocab=151936, norm="rms", act="swiglu",
        qk_norm=True, rope_theta=1e6,
        n_experts=128, n_experts_per_tok=8, moe_d_ff=768,
        expert_layer_period=1, router_type="softmax",
        router_norm_topk=True, moe_backend="lcx", capacity_factor=1.25,
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab=128, qk_norm=True,
        n_experts=8, n_experts_per_tok=2, moe_d_ff=48,
        moe_backend="sort", capacity_factor=4.0,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
