"""hubert-xlarge  [audio]  48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 — encoder-only, same arch as w2v2  [arXiv:2106.07447;
unverified].  Modality frontend is a STUB: input_specs provides
precomputed frame embeddings [B, S, d_model]."""
import jax.numpy as jnp

from .base import ModelConfig, register


@register("hubert-xlarge")
def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
        vocab=504, causal=False, norm="layer", act="gelu",
        frontend="audio", max_seq_len=4096,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab=31, causal=False, norm="layer", act="gelu",
        frontend="audio",
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=16,
    )
