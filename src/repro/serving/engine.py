"""Serving engine: slot-based KV cache with continuous batching.

The engine owns a fixed pool of ``n_slots`` sequences sharing one
pre-allocated cache (`repro.models.init_cache`).  New requests prefill
into free slots; every decode tick advances *all* active slots with one
compiled ``decode_step`` (single-token, full-batch — the decode_* cells
of the benchmark matrix lower exactly this function).

Hardware note: prefill and decode are separate jit programs (different
shapes); the decode program is cache-resident and memory-bound — its
roofline terms come from the dry-run of ``serve_step``.

Per-slot state (lengths, completion) is host-side; the device-side
decode uses per-slot length masks so slots at different positions can
coexist in one batch (continuous batching).

Scheduling: each ``tick`` is driven through an AMT executor
(`repro.amt.Executor`) — one admission task per queued request
(priority = arrival order) and one decode task depending on all of
them, so prefill admission and decode advancement are ordinary tasks a
larger task graph can compose with.  ``use_executor=False`` keeps the
inline loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import decode_step, init_cache, prefill
from repro.models.model import cache_batch_axes

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    n_slots: int = 8
    max_seq: int = 512
    temperature: float = 0.0          # 0 = greedy
    eos_token: Optional[int] = None
    max_new_tokens: int = 64
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [P] int32
    max_new_tokens: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None        # set when the request was evicted
    submitted_at: float = 0.0
    finished_at: float = 0.0


def sample_token(logits: jax.Array, temperature: float,
                 key: jax.Array) -> jax.Array:
    """logits [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def make_decode_fn(cfg: Any, kernels: Optional[Dict[str, Any]] = None):
    """Per-slot-length decode step: tokens [B,1], lengths [B].

    Uses a vmapped length so slots at different fill levels share the
    batch (the model's scalar-length path is the uniform-batch special
    case used by the decode_* dry-run cells)."""

    def step(params: PyTree, tokens: jax.Array, caches: PyTree,
             lengths: jax.Array) -> Tuple[jax.Array, PyTree]:
        def one(p, tok, cache, ln):
            # vmap stripped the slot dim; re-add a batch dim of 1 at the
            # per-leaf batch axis for the model's batched decode
            axes = cache_batch_axes(cfg, cache)
            cache_b = jax.tree.map(jnp.expand_dims, cache, axes)
            lg, nc = decode_step(cfg, p, tok[None], cache_b, ln,
                                 kernels=kernels)
            nc = jax.tree.map(lambda t, a: jnp.squeeze(t, a), nc, axes)
            return lg[0], nc

        # vmap over the slot dimension (batch axis differs between
        # prefix caches and scan-stacked caches)
        cache_axes = cache_batch_axes(cfg, caches)
        lg, new_caches = jax.vmap(
            one, in_axes=(None, 0, cache_axes, 0),
            out_axes=(0, cache_axes))(params, tokens, caches, lengths)
        return lg, new_caches

    return step


class ServingEngine:
    def __init__(self, cfg: Any, params: PyTree, scfg: ServeConfig,
                 kernels: Optional[Dict[str, Any]] = None, *,
                 use_executor: bool = True,
                 lcx_runtime: Optional[Any] = None,
                 lcx_device: Optional[Any] = None,
                 failover: bool = False,
                 heartbeat: Optional[Any] = None) -> None:
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.kernels = kernels
        self.heartbeat: Optional[Any] = heartbeat
        self.standby_device: Optional[Any] = None
        if use_executor:
            import repro.core as lcx
            from repro.amt import Executor
            # Library-interop pattern (docs/resources.md): the engine owns
            # a private LCX runtime unless the application injects one, so
            # its admission traffic never mixes with — or depends on — the
            # process-global default runtime.
            if lcx_runtime is None and lcx_device is not None:
                lcx_runtime = lcx_device.runtime
            if lcx_runtime is None:
                lcx_runtime = lcx.Runtime(name="serving")
            self.lcx_runtime: Optional[Any] = lcx_runtime
            self._executor: Optional[Executor] = Executor(
                name="serving", runtime=lcx_runtime, device=lcx_device)
            if failover or heartbeat is not None:
                from repro.runtime.fault import HeartbeatMonitor
                # Warm standby on the serving device's axis: if the
                # heartbeat declares the primary dead mid-stream, its
                # endpoints and in-flight admission traffic migrate here
                # and the executor re-dispatches the affected tasks.
                primary = self._executor.device
                self.standby_device = lcx_runtime.device(axis=primary.axis)
                if self.heartbeat is None:
                    self.heartbeat = HeartbeatMonitor(on_dead="failover")
                self.heartbeat.attach(lcx_runtime)
        else:
            self.lcx_runtime = lcx_runtime
            self._executor = None
        self.caches = init_cache(cfg, scfg.n_slots, scfg.max_seq)
        self.lengths = np.zeros((scfg.n_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * scfg.n_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.failed: List[Request] = []
        self._key = jax.random.PRNGKey(scfg.seed)
        self._decode = jax.jit(make_decode_fn(cfg, kernels))
        self._prefill_cache: Dict[int, Any] = {}
        self.stats = {"ticks": 0, "prefills": 0, "decoded_tokens": 0,
                      "evictions": 0}

    # -- request intake ------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            cfg, kernels = self.cfg, self.kernels

            def one(params, toks, cache):
                axes = cache_batch_axes(cfg, cache)
                cache_b = jax.tree.map(jnp.expand_dims, cache, axes)
                lg, nc = prefill(cfg, params, toks[None], cache_b,
                                 kernels=kernels)
                nc = jax.tree.map(lambda t, a: jnp.squeeze(t, a), nc, axes)
                return lg[0, -1], nc

            self._prefill_cache[plen] = jax.jit(one)
        return self._prefill_cache[plen]

    def _admit(self) -> None:
        while self._free_slots() and self.queue:
            req = self.queue.pop(0)
            self._admit_one(req)

    def _evict(self, req: Request, reason: str) -> None:
        """Terminally fail ``req`` without touching slot state: the tick
        loop keeps serving the other slots instead of wedging."""
        req.done = True
        req.error = reason
        req.finished_at = time.perf_counter()
        self.finished.append(req)
        self.failed.append(req)
        self.stats["evictions"] += 1

    def _admit_one(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot.  Returns False when no slot
        is free (caller re-queues); True when the request was placed or
        terminally handled (including eviction on prefill failure)."""
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        plen = len(req.prompt)
        if plen >= self.scfg.max_seq:
            self._evict(req, f"prompt length {plen} >= max_seq "
                             f"{self.scfg.max_seq}")
            return True
        axes = cache_batch_axes(self.cfg, self.caches)
        try:
            toks = jnp.asarray(req.prompt, jnp.int32)
            slot_cache = jax.tree.map(
                lambda t, a: jnp.take(t, slot, axis=a), self.caches, axes)
            # exact-length prefill: one compiled program per distinct
            # prompt length (bucketing would corrupt SSM prefill state —
            # the recurrent state cannot mask padding the way KV rows can)
            lg, new_cache = self._prefill_fn(plen)(
                self.params, toks, slot_cache)
        except Exception as e:
            # the shared cache was not written yet — evict the request
            # and leave the slot free for the next one
            self._evict(req, f"prefill failed: {type(e).__name__}: {e}")
            return True
        self.caches = jax.tree.map(
            lambda buf, nc, a: jax.lax.dynamic_update_slice_in_dim(
                buf, jnp.expand_dims(nc, a).astype(buf.dtype),
                slot, axis=a),
            self.caches, new_cache, axes)
        self.lengths[slot] = plen
        self.slot_req[slot] = req
        self.stats["prefills"] += 1
        # sample the first generated token from the prefill logits
        self._key, sub = jax.random.split(self._key)
        tok = int(np.asarray(sample_token(
            lg[None], self.scfg.temperature, sub))[0])
        req.output.append(tok)
        self.stats["decoded_tokens"] += 1
        # the first token may already terminate the request
        limit = req.max_new_tokens or self.scfg.max_new_tokens
        if (self.scfg.eos_token is not None
                and tok == self.scfg.eos_token) \
                or len(req.output) >= limit:
            req.done = True
            req.finished_at = time.perf_counter()
            self.finished.append(req)
            self.slot_req[slot] = None
            self.lengths[slot] = 0
        return True

    # -- decode tick ----------------------------------------------------------
    def tick(self) -> int:
        """Admit + one decode step for all active slots.  Returns the
        number of live slots advanced.

        With an executor, admission and decode run as a per-tick task
        graph: one prefill-admission task per queued request (priority
        keeps arrival order) feeding one decode task."""
        if self._executor is not None:
            return self._tick_executor()
        self._admit()
        return self._decode_tick()

    def _tick_executor(self) -> int:
        ex = self._executor
        queued, self.queue = list(self.queue), []
        admissions = []
        for k, req in enumerate(queued):
            def admit(ctx, _req=req):
                if not self._admit_one(_req):
                    self.queue.append(_req)   # no free slot: re-queue

            admissions.append(ex.spawn(
                admit, priority=len(queued) - k,
                name=f"prefill:{req.rid}"))
        decode = ex.spawn(lambda ctx: self._decode_tick(),
                          deps=tuple(admissions), priority=-1,
                          name="decode")
        ex.run()
        return decode.result

    def _decode_tick(self) -> int:
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.scfg.n_slots, 1), np.int32)
        for i in active:
            req = self.slot_req[i]
            tokens[i, 0] = req.output[-1] if req.output \
                else req.prompt[-1]
        lengths = jnp.asarray(self.lengths)
        lg, self.caches = self._decode(self.params, jnp.asarray(tokens),
                                       self.caches, lengths)
        self._key, sub = jax.random.split(self._key)
        nxt = np.asarray(sample_token(lg[:, 0] if lg.ndim == 3 else lg,
                                      self.scfg.temperature, sub))
        self.stats["ticks"] += 1
        for i in active:
            req = self.slot_req[i]
            self.lengths[i] += 1
            tok = int(nxt[i])
            req.output.append(tok)
            self.stats["decoded_tokens"] += 1
            limit = req.max_new_tokens or self.scfg.max_new_tokens
            if (self.scfg.eos_token is not None
                    and tok == self.scfg.eos_token) \
                    or len(req.output) >= limit \
                    or self.lengths[i] >= self.scfg.max_seq - 1:
                req.done = True
                req.finished_at = time.perf_counter()
                self.finished.append(req)
                self.slot_req[i] = None
                self.lengths[i] = 0
        return len(active)

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.tick()
        return self.finished
