from .engine import ServeConfig, ServingEngine, Request, sample_token

__all__ = ["ServeConfig", "ServingEngine", "Request", "sample_token"]
