"""The Trainer: jit-compiled train step, microbatched gradient
accumulation (f32 or int8+error-feedback), checkpoint/restart, failure
recovery, straggler-triggered elastic remesh.

Every distributed boundary in the step goes through the sharding rules
(`repro.parallel.sharding`) and — for MoE dispatch, ring collectives and
pipeline transfers — through LCX ops, mirroring how HPX/PaRSEC route
parcels through LCI.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.data import DataLoader, SyntheticLMDataset
from repro.models import init_model, loss_fn
from repro.optim import (AdamWState, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule)
from repro.parallel.sharding import (dp_axes, logical_spec, param_shardings,
                                     set_active_mesh)
from .fault import (FailureInjector, NodeFailure, StragglerMonitor,
                    elastic_reshard)

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    grad_accum: int = 1
    compressed_accum: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0
    seq_len: int = 512
    global_batch: int = 8
    straggler_threshold: float = 2.0
    straggler_patience: int = 3
    donate: bool = True


def make_train_step(cfg: Any, tcfg: TrainConfig,
                    lr_fn: Callable[[jax.Array], jax.Array],
                    kernels: Optional[Dict[str, Any]] = None):
    """Pure train step: (params, opt, batch) -> (params, opt, metrics)."""
    accum = max(tcfg.grad_accum, 1)

    def loss_of(p: PyTree, b: Dict[str, jax.Array]):
        return loss_fn(cfg, p, b, kernels=kernels)

    def step(params: PyTree, opt: AdamWState, batch: Dict[str, jax.Array]):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            # split the batch into microbatches along dim 0 and scan;
            # the accumulator is f32 (or int8+EF via CompressedAccumulator
            # when tcfg.compressed_accum — see repro.optim.compression)
            def micro(b, i):
                return jax.tree.map(
                    lambda t: jax.lax.dynamic_slice_in_dim(
                        t, i * (t.shape[0] // accum),
                        t.shape[0] // accum, 0), b)

            if tcfg.compressed_accum:
                from repro.optim import CompressedAccumulator as CA
                acc = CA.init(params)
                metrics = None
                for i in range(accum):
                    (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                        params, micro(batch, i))
                    acc = CA.add(acc, g)
                    metrics = m if metrics is None else jax.tree.map(
                        lambda a, b_: a + b_, metrics, m)
                grads = CA.value(acc, accum)
                metrics = jax.tree.map(lambda t: t / accum, metrics)
            else:
                def body(carry, i):
                    gsum, msum = carry
                    (l, m), g = jax.value_and_grad(
                        loss_of, has_aux=True)(params, micro(batch, i))
                    gsum = jax.tree.map(
                        lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                    msum = jax.tree.map(lambda a, b_: a + b_, msum, m)
                    return (gsum, msum), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                m0 = {"xent": 0.0, "aux": 0.0, "loss": 0.0}
                if cfg.mtp_depth:
                    m0["mtp"] = 0.0
                m0 = jax.tree.map(jnp.float32, m0)
                (grads, metrics), _ = jax.lax.scan(
                    body, (zeros, m0), jnp.arange(accum))
                grads = jax.tree.map(lambda g: g / accum, grads)
                metrics = jax.tree.map(lambda t: t / accum, metrics)

        grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
        lr = lr_fn(opt.step)
        params, opt = adamw_update(params, grads, opt, lr=lr,
                                   weight_decay=tcfg.weight_decay)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt, metrics

    return step


class Trainer:
    def __init__(self, cfg: Any, tcfg: TrainConfig,
                 mesh: Optional[Mesh] = None,
                 kernels: Optional[Dict[str, Any]] = None,
                 failure_injector: Optional[FailureInjector] = None,
                 lcx_runtime: Optional[Any] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.kernels = kernels
        self.injector = failure_injector
        self.lcx_runtime = lcx_runtime
        if (self.injector is not None and lcx_runtime is not None
                and self.injector.runtime is None):
            self.injector.runtime = lcx_runtime
        self.monitor = StragglerMonitor(tcfg.straggler_threshold,
                                        tcfg.straggler_patience)
        self.ckpt = (AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
                     if tcfg.ckpt_dir else None)
        self.step_count = 0
        self.metrics_log: list = []
        self._build()

    # -- construction -------------------------------------------------------
    def _build(self) -> None:
        cfg, tcfg = self.cfg, self.tcfg
        set_active_mesh(self.mesh)
        key = jax.random.PRNGKey(tcfg.seed)

        if self.mesh is not None:
            from repro.models.model import abstract_init
            params_proto, dims = abstract_init(cfg, key)
            self.param_sharding = param_shardings(dims, params_proto,
                                                  self.mesh)
            init_jit = jax.jit(lambda k: init_model(k, cfg)[0],
                               out_shardings=self.param_sharding)
            self.params = init_jit(key)
            self.dims = dims
        else:
            self.params, self.dims = init_model(key, cfg)
            self.param_sharding = None

        self.opt = self._init_opt()
        self.lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)
        self._step_fn = self._compile_step()
        self.loader = self._make_loader(start_step=0)

    def _init_opt(self) -> AdamWState:
        if self.param_sharding is not None:
            opt_shardings = AdamWState(
                step=NamedSharding(self.mesh, P()),
                m=self.param_sharding, v=self.param_sharding)
            return jax.jit(
                lambda p: adamw_init(p, self.cfg.opt_dtype),
                out_shardings=opt_shardings)(self.params)
        return adamw_init(self.params, self.cfg.opt_dtype)

    def _compile_step(self):
        step = make_train_step(self.cfg, self.tcfg, self.lr_fn,
                               self.kernels)
        donate = (0, 1) if self.tcfg.donate else ()
        return jax.jit(step, donate_argnums=donate)

    def batch_sharding(self) -> Dict[str, NamedSharding]:
        if self.mesh is None:
            return {}
        spec3 = NamedSharding(self.mesh, logical_spec(
            ("batch", None, None), None, self.mesh))
        spec2 = NamedSharding(self.mesh, logical_spec(
            ("batch", None), None, self.mesh))
        out = {"tokens": spec2, "labels": spec2}
        if self.cfg.family == "audio" or self.cfg.frontend_len:
            out["frontend"] = spec3
        return out

    def _make_loader(self, start_step: int) -> Optional[DataLoader]:
        tcfg, cfg = self.tcfg, self.cfg
        ds = SyntheticLMDataset(
            cfg.vocab, tcfg.seq_len, tcfg.global_batch, seed=tcfg.seed,
            frontend_len=cfg.frontend_len, frontend_dim=cfg.d_model,
            family=cfg.family)
        shardings = self.batch_sharding()
        if not shardings:
            return None
        return DataLoader(ds, shardings, start_step=start_step)

    def _host_batch(self, step: int) -> Dict[str, jax.Array]:
        ds = SyntheticLMDataset(
            self.cfg.vocab, self.tcfg.seq_len, self.tcfg.global_batch,
            seed=self.tcfg.seed, frontend_len=self.cfg.frontend_len,
            frontend_dim=self.cfg.d_model, family=self.cfg.family)
        return {k: jnp.asarray(v) for k, v in ds.batch(step).items()}

    # -- checkpoint / restore ------------------------------------------------
    def save(self, blocking: bool = False) -> None:
        if self.ckpt is None:
            return
        state = {"params": self.params, "opt": self.opt}
        self.ckpt.save(self.step_count, state,
                       extra={"step_count": self.step_count})
        if blocking:
            self.ckpt.wait()

    def restore(self) -> bool:
        if self.tcfg.ckpt_dir is None:
            return False
        step = latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return False
        target = {"params": self.params, "opt": self.opt}
        shardings = None
        if self.param_sharding is not None:
            shardings = {"params": self.param_sharding,
                         "opt": AdamWState(
                             step=NamedSharding(self.mesh, P()),
                             m=self.param_sharding,
                             v=self.param_sharding)}
        state, step, extra = restore_checkpoint(
            self.tcfg.ckpt_dir, target, shardings=shardings)
        self.params, self.opt = state["params"], state["opt"]
        self.step_count = extra.get("step_count", step)
        if self.loader is not None:
            self.loader.close()
            self.loader = self._make_loader(start_step=self.step_count)
        return True

    # -- elastic remesh -----------------------------------------------------
    def remesh(self, new_mesh: Mesh) -> None:
        """Move live state to a new mesh (shrink after failure or grow on
        recovery), rebuild the compiled step and the loader."""
        if self.ckpt is not None:
            self.ckpt.wait()
        set_active_mesh(new_mesh)
        self.mesh = new_mesh
        params_proto = jax.eval_shape(lambda p: p, self.params)
        self.param_sharding = param_shardings(self.dims, params_proto,
                                              new_mesh)
        self.params = elastic_reshard(self.params, self.param_sharding)
        opt_shardings = AdamWState(
            step=NamedSharding(new_mesh, P()),
            m=self.param_sharding, v=self.param_sharding)
        self.opt = elastic_reshard(self.opt, opt_shardings)
        self._step_fn = self._compile_step()
        if self.loader is not None:
            self.loader.close()
        self.loader = self._make_loader(start_step=self.step_count)

    # -- throughput accounting -------------------------------------------
    def _flops_per_step(self) -> float:
        """6·N_active·tokens — the MFU yardstick (EXPERIMENTS.md
        §Roofline conventions)."""
        if not hasattr(self, "_mf_cache"):
            from repro.analysis.roofline import model_flops
            from repro.models.model import abstract_init
            proto, _ = abstract_init(self.cfg)
            self._mf_cache = model_flops(
                self.cfg, proto, "train", self.tcfg.seq_len,
                self.tcfg.global_batch)
        return self._mf_cache

    def achieved_flops(self, dt: float) -> float:
        return self._flops_per_step() / max(dt, 1e-9)

    # -- run loop ------------------------------------------------------------
    def run(self, n_steps: int, max_failures: int = 8) -> Dict[str, Any]:
        failures = 0
        end = self.step_count + n_steps
        # step-0 checkpoint: recovery is possible from the very first
        # step (a failure before any commit would otherwise be fatal)
        if self.ckpt is not None and latest_step(self.tcfg.ckpt_dir) is None:
            self.save(blocking=True)
        while self.step_count < end:
            try:
                self._run_until(end)
            except NodeFailure as e:
                failures += 1
                if failures > max_failures:
                    raise
                # recovery: restore last committed state and continue
                restored = self.restore()
                if not restored:
                    raise RuntimeError(
                        "node failure before any checkpoint") from e
        if self.ckpt is not None:
            self.save(blocking=True)
        return {"final_step": self.step_count,
                "failures": failures,
                "straggler_events": list(self.monitor.events),
                "metrics": self.metrics_log[-1] if self.metrics_log else {}}

    def _run_until(self, end: int) -> None:
        while self.step_count < end:
            if self.injector is not None:
                self.injector.check(self.step_count)
            if self.loader is not None:
                _, batch = next(self.loader)
            else:
                batch = self._host_batch(self.step_count)
            t0 = time.perf_counter()
            self.params, self.opt, metrics = self._step_fn(
                self.params, self.opt, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_count += 1
            verdict = self.monitor.observe(self.step_count, dt)
            if self.step_count % self.tcfg.log_every == 0 \
                    or self.step_count == end:
                self.metrics_log.append(
                    {"step": self.step_count,
                     **{k: float(v) for k, v in metrics.items()},
                     "dt": dt, "straggler": verdict,
                     "tokens_per_s": self.tcfg.seq_len
                     * self.tcfg.global_batch / dt,
                     "model_flops_per_s": self.achieved_flops(dt)})
            if self.tcfg.ckpt_dir and \
                    self.step_count % self.tcfg.ckpt_every == 0:
                self.save()
