from .fault import (FailureInjector, HeartbeatMonitor, NodeFailure,
                    StragglerMonitor, elastic_reshard, fail_device,
                    shrink_mesh_shape)
from .trainer import TrainConfig, Trainer, make_train_step

__all__ = ["FailureInjector", "HeartbeatMonitor", "NodeFailure",
           "StragglerMonitor",
           "elastic_reshard", "fail_device", "shrink_mesh_shape",
           "TrainConfig", "Trainer", "make_train_step"]
