"""Fault tolerance machinery: failure detection, straggler mitigation,
elastic remesh.

On a real cluster the failure signal comes from the coordinator (a jax
distributed heartbeat / barrier timeout); here the same control flow is
driven by injectable signals so every policy is testable on CPU:

- :class:`FailureInjector` raises ``NodeFailure`` at chosen steps.
- :class:`StragglerMonitor` keeps an EMA of step time and flags steps
  slower than ``threshold ×`` EMA; after ``patience`` consecutive flags
  it recommends a remesh (drop the slow host) — the AMT-style answer to
  stragglers (work steals around slow nodes; SPMD can only reshape).
- :class:`HeartbeatMonitor` watches per-device heartbeats (progress-tick
  driven, same EMA idiom) and declares silently dead devices, triggering
  live endpoint failover (``runtime.failover``), a fatal drain, or a
  raised ``NodeFailure`` per its ``on_dead`` policy.
- :func:`elastic_reshard` moves live state onto a new mesh.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

PyTree = Any


class NodeFailure(RuntimeError):
    """Raised when a (simulated) node drops out of the job."""

    def __init__(self, msg: str, lost_devices: int = 0) -> None:
        super().__init__(msg)
        self.lost_devices = lost_devices


class FailureInjector:
    """Deterministic failure schedule for tests/examples.

    ``devices`` optionally names LCX :class:`~repro.core.Device` objects
    to kill when the failure fires: each is marked dead and its pending
    transfer ledger drains as ``fatal`` completion events (see
    :func:`fail_device`), so comm-blocked waiters observe the loss
    instead of hanging."""

    def __init__(self, fail_at: Sequence[int] = (),
                 lost_devices: int = 0,
                 devices: Sequence[Any] = (),
                 runtime: Optional[Any] = None) -> None:
        self.fail_at = set(fail_at)
        self.lost_devices = lost_devices
        self.devices = list(devices)
        self.runtime = runtime
        self.fired: List[int] = []

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.fired.append(step)
            for dev in self.devices:
                fail_device(dev, runtime=self.runtime)
            raise NodeFailure(f"injected node failure at step {step}",
                              self.lost_devices)


def fail_device(device: Any, runtime: Optional[Any] = None) -> int:
    """Mark an LCX device dead and drain its pending ledger as ``fatal``
    completions.  Returns the number of transfers drained.  This is the
    bridge from :class:`NodeFailure` to the comm layer: completion
    objects waiting on the dead device observe ``ErrorCode.FATAL``
    events (no infinite hang) and the caller can proceed to
    :func:`elastic_reshard`.

    The ledger drained is, in order: the explicitly passed ``runtime``,
    the device's own runtime (hierarchy-created devices), else the
    global default."""
    device.mark_dead()
    rt = runtime
    if rt is None:
        rt = getattr(device, "runtime", None)
    if rt is None:
        from repro.core import runtime as _global  # core stays optional
        rt = _global()
    return rt.drain_dead(device)


class StragglerMonitor:
    """EMA-based straggler detection with a remesh recommendation."""

    def __init__(self, threshold: float = 2.0, patience: int = 3,
                 ema_decay: float = 0.9) -> None:
        self.threshold = threshold
        self.patience = patience
        self.ema_decay = ema_decay
        self.ema: Optional[float] = None
        self.slow_streak = 0
        self.events: List[Dict[str, float]] = []

    def observe(self, step: int, dt: float) -> str:
        """-> 'ok' | 'slow' | 'remesh'."""
        if self.ema is None:
            self.ema = dt
            return "ok"
        verdict = "ok"
        if dt > self.threshold * self.ema:
            self.slow_streak += 1
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
            verdict = "slow"
            if self.slow_streak >= self.patience:
                verdict = "remesh"
                self.slow_streak = 0
        else:
            self.slow_streak = 0
            # only fold healthy steps into the EMA
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return verdict


class HeartbeatMonitor:
    """Progress-tick-driven device liveness detection with automatic
    failover (builds on :class:`StragglerMonitor`'s EMA idiom).

    Every ``lcx.progress()`` call pings the runtime's devices: each
    alive, responsive device records a beat (``device.last_beat`` =
    current tick), then the monitor polls.  A healthy device's
    inter-beat gap folds into a per-device EMA; a device whose current
    gap exceeds ``threshold ×`` EMA (and at least ``grace`` ticks) for
    ``patience`` consecutive polls is declared dead:

    - ``on_dead="failover"`` — ``runtime.failover(dev)``: endpoints,
      un-matched posted ops, and in-flight ledger entries migrate onto
      the least-loaded survivor (see ``NetContext.migrate``).
    - ``on_dead="drain"``   — :func:`fail_device`: the classic fatal
      drain (completion objects observe the loss).
    - ``on_dead="raise"``   — raise :class:`NodeFailure` out of the
      progress call.

    Attach with ``monitor.attach(rt)`` (sets ``rt.heartbeat``);
    ``monitor.events`` records every declaration for postmortems and
    recovery-latency measurement (``failoverbench.py``)."""

    POLICIES = ("failover", "drain", "raise")

    def __init__(self, threshold: float = 3.0, patience: int = 2,
                 grace: int = 4, ema_decay: float = 0.9,
                 on_dead: str = "failover", replay: bool = True) -> None:
        if on_dead not in self.POLICIES:
            raise ValueError(f"unknown on_dead policy {on_dead!r}")
        self.threshold = threshold
        self.patience = patience
        self.grace = max(1, grace)
        self.ema_decay = ema_decay
        self.on_dead = on_dead
        self.replay = replay
        # per-device (id-keyed): EMA of inter-beat gaps, last seen beat,
        # consecutive suspect polls
        self._ema: Dict[int, float] = {}
        self._seen_beat: Dict[int, int] = {}
        self._suspect: Dict[int, int] = {}
        self.events: List[Dict[str, Any]] = []

    def attach(self, runtime: Any) -> "HeartbeatMonitor":
        runtime.heartbeat = self
        return self

    def poll(self, runtime: Any) -> List[Any]:
        """Called by ``progress()`` after the beat sweep.  Returns the
        devices declared dead this poll (already handled per policy)."""
        declared: List[Any] = []
        tick = runtime.tick
        for dev in runtime.devices():
            if not dev.alive:
                continue
            key = id(dev)
            seen = self._seen_beat.get(key)
            if seen is None:
                # first sighting: start the clock at this tick
                self._seen_beat[key] = dev.last_beat or tick
                continue
            if dev.last_beat > seen:
                gap = dev.last_beat - seen
                self._seen_beat[key] = dev.last_beat
                self._suspect[key] = 0
                prev = self._ema.get(key)
                self._ema[key] = gap if prev is None else (
                    self.ema_decay * prev + (1 - self.ema_decay) * gap)
                continue
            # no beat since last poll: how overdue is it?
            gap = tick - seen
            expected = max(self._ema.get(key, 1.0), 1.0)
            if gap >= self.grace and gap > self.threshold * expected:
                self._suspect[key] = self._suspect.get(key, 0) + 1
                if self._suspect[key] >= self.patience:
                    declared.append(dev)
                    self._suspect[key] = 0
        for dev in declared:
            self._declare_dead(runtime, dev)
        return declared

    def _declare_dead(self, runtime: Any, dev: Any) -> None:
        event: Dict[str, Any] = {"tick": runtime.tick, "device": dev,
                                 "policy": self.on_dead}
        if self.on_dead == "failover":
            try:
                report = runtime.failover(dev, replay=self.replay)
                event["target"] = report.target
                event["report"] = report
            except RuntimeError as e:
                # no survivor left: degrade to the fatal drain
                event["policy"] = "drain"
                event["error"] = str(e)
                fail_device(dev, runtime=runtime)
        elif self.on_dead == "drain":
            fail_device(dev, runtime=runtime)
        self.events.append(event)
        if self.on_dead == "raise":
            dev.mark_dead()
            raise NodeFailure(
                f"heartbeat lost on {dev!r} at tick {runtime.tick}", 1)


def elastic_reshard(tree: PyTree, shardings: PyTree) -> PyTree:
    """Move live state onto new shardings (new mesh).  Works for both
    shrink (node loss) and grow (node recovery) as long as the global
    shapes are unchanged."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda t: isinstance(t, jax.Array))


def shrink_mesh_shape(shape: Dict[str, int], lost: int) -> Dict[str, int]:
    """Halve the data axis until the lost devices are covered — the
    remesh policy used when a host drops (model axis is preserved so
    parameter layouts stay valid).  Losing ANY device forces at least
    one halving (the dead host's row is gone).

    Each halving removes ``data/2 × (product of the other axes)``
    *actual* devices; the count accumulates until it reaches ``lost``
    (or the data axis bottoms out at 1)."""
    new = dict(shape)
    other = 1
    for axis, n in new.items():
        if axis != "data":
            other *= n
    covered = 0
    while covered < max(lost, 1) and new.get("data", 1) > 1:
        new["data"] //= 2
        covered += new["data"] * other
    return new
