"""Fault tolerance machinery: failure detection, straggler mitigation,
elastic remesh.

On a real cluster the failure signal comes from the coordinator (a jax
distributed heartbeat / barrier timeout); here the same control flow is
driven by injectable signals so every policy is testable on CPU:

- :class:`FailureInjector` raises ``NodeFailure`` at chosen steps.
- :class:`StragglerMonitor` keeps an EMA of step time and flags steps
  slower than ``threshold ×`` EMA; after ``patience`` consecutive flags
  it recommends a remesh (drop the slow host) — the AMT-style answer to
  stragglers (work steals around slow nodes; SPMD can only reshape).
- :func:`elastic_reshard` moves live state onto a new mesh.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

PyTree = Any


class NodeFailure(RuntimeError):
    """Raised when a (simulated) node drops out of the job."""

    def __init__(self, msg: str, lost_devices: int = 0) -> None:
        super().__init__(msg)
        self.lost_devices = lost_devices


class FailureInjector:
    """Deterministic failure schedule for tests/examples.

    ``devices`` optionally names LCX :class:`~repro.core.Device` objects
    to kill when the failure fires: each is marked dead and its pending
    transfer ledger drains as ``fatal`` completion events (see
    :func:`fail_device`), so comm-blocked waiters observe the loss
    instead of hanging."""

    def __init__(self, fail_at: Sequence[int] = (),
                 lost_devices: int = 0,
                 devices: Sequence[Any] = (),
                 runtime: Optional[Any] = None) -> None:
        self.fail_at = set(fail_at)
        self.lost_devices = lost_devices
        self.devices = list(devices)
        self.runtime = runtime
        self.fired: List[int] = []

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.fired.append(step)
            for dev in self.devices:
                fail_device(dev, runtime=self.runtime)
            raise NodeFailure(f"injected node failure at step {step}",
                              self.lost_devices)


def fail_device(device: Any, runtime: Optional[Any] = None) -> int:
    """Mark an LCX device dead and drain its pending ledger as ``fatal``
    completions.  Returns the number of transfers drained.  This is the
    bridge from :class:`NodeFailure` to the comm layer: completion
    objects waiting on the dead device observe ``ErrorCode.FATAL``
    events (no infinite hang) and the caller can proceed to
    :func:`elastic_reshard`.

    The ledger drained is, in order: the explicitly passed ``runtime``,
    the device's own runtime (hierarchy-created devices), else the
    global default."""
    device.mark_dead()
    rt = runtime
    if rt is None:
        rt = getattr(device, "runtime", None)
    if rt is None:
        from repro.core import runtime as _global  # core stays optional
        rt = _global()
    return rt.drain_dead(device)


class StragglerMonitor:
    """EMA-based straggler detection with a remesh recommendation."""

    def __init__(self, threshold: float = 2.0, patience: int = 3,
                 ema_decay: float = 0.9) -> None:
        self.threshold = threshold
        self.patience = patience
        self.ema_decay = ema_decay
        self.ema: Optional[float] = None
        self.slow_streak = 0
        self.events: List[Dict[str, float]] = []

    def observe(self, step: int, dt: float) -> str:
        """-> 'ok' | 'slow' | 'remesh'."""
        if self.ema is None:
            self.ema = dt
            return "ok"
        verdict = "ok"
        if dt > self.threshold * self.ema:
            self.slow_streak += 1
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
            verdict = "slow"
            if self.slow_streak >= self.patience:
                verdict = "remesh"
                self.slow_streak = 0
        else:
            self.slow_streak = 0
            # only fold healthy steps into the EMA
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return verdict


def elastic_reshard(tree: PyTree, shardings: PyTree) -> PyTree:
    """Move live state onto new shardings (new mesh).  Works for both
    shrink (node loss) and grow (node recovery) as long as the global
    shapes are unchanged."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda t: isinstance(t, jax.Array))


def shrink_mesh_shape(shape: Dict[str, int], lost: int) -> Dict[str, int]:
    """Halve the data axis until the lost devices are covered — the
    remesh policy used when a host drops (model axis is preserved so
    parameter layouts stay valid).  Losing ANY device forces at least
    one halving (the dead host's row is gone).

    Each halving removes ``data/2 × (product of the other axes)``
    *actual* devices; the count accumulates until it reaches ``lost``
    (or the data axis bottoms out at 1)."""
    new = dict(shape)
    other = 1
    for axis, n in new.items():
        if axis != "data":
            other *= n
    covered = 0
    while covered < max(lost, 1) and new.get("data", 1) > 1:
        new["data"] //= 2
        covered += new["data"] * other
    return new
