from .store import (AsyncCheckpointer, latest_step, restore_checkpoint,
                    save_checkpoint, list_steps)

__all__ = ["AsyncCheckpointer", "latest_step", "restore_checkpoint",
           "save_checkpoint", "list_steps"]
