"""Checkpointing: atomic, async, resumable.

Layout::

    <dir>/step_000123/
        leaf_00000.npy ...        one file per pytree leaf
        manifest.json             treedef + leaf names/shapes/dtypes
        COMMIT                    written last — presence marks validity

Writes go to ``step_N.tmp`` and are renamed only after COMMIT exists, so
a crash mid-write never corrupts the restore path (the fault-tolerance
loop in `repro.runtime` restarts from ``latest_step``).  The async
writer snapshots device arrays to host (blocking only for D2H) and does
file I/O on a worker thread so training continues during the write.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _leaf_paths(tree: PyTree) -> Tuple[List[str], List[Any], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(kp) for kp, _ in flat]
    leaves = [v for _, v in flat]
    return names, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    names, leaves, _ = _leaf_paths(tree)
    host = [np.asarray(x) for x in leaves]
    return _write(ckpt_dir, step, names, host, extra)


def _write(ckpt_dir: str, step: int, names: List[str],
           host: List[np.ndarray], extra: Optional[Dict[str, Any]]) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, arr) in enumerate(zip(names, host)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, d)
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(full, "COMMIT")):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, target: PyTree,
                       step: Optional[int] = None,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[PyTree, int, Dict[str, Any]]:
    """Restore into the structure of ``target``.  With ``shardings``
    (mirroring the tree), leaves are placed directly onto devices."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _leaf_paths(target)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    out_leaves = []
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        if shardings is not None else [None] * len(leaves))
    for name, ref, sh in zip(names, leaves, shard_leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(d, by_name[name]["file"]))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != "
                f"target {ref.shape} — reshard-restore requires matching "
                "global shapes")
        if sh is not None:
            out_leaves.append(jax.device_put(arr, sh))
        else:
            out_leaves.append(jax.device_put(
                arr.astype(np.dtype(jax.numpy.dtype(ref.dtype)))))
    return (jax.tree_util.tree_unflatten(treedef, out_leaves), step,
            manifest.get("extra", {}))


class AsyncCheckpointer:
    """Snapshot to host synchronously, write files on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3) -> None:
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        names, leaves, _ = _leaf_paths(tree)
        host = [np.asarray(x) for x in leaves]   # D2H, blocking

        def work():
            try:
                _write(self.ckpt_dir, step, names, host, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = list_steps(self.ckpt_dir)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
