"""LCX communication-posting operations (paper §2.2) as objectized
flexible functions (paper §3.1).

All posting operations are **asynchronous**: they pend the operation and
return a :class:`PostHandle`.  Completion is observed through the
completion object passed via ``.comp(...)`` (or an auto-allocated
:class:`~repro.core.resources.Synchronizer`) *after* an explicit
:func:`progress` call — the paper's explicit-progress design point.

Naming follows the binding guideline: flexible form ``send_x``, plain
shorthand ``send`` with positional arguments only.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .flex import FlexOp, plain
from .resources import (CompletionObject, CompletionQueue, Device, Endpoint,
                        ErrorCode, Event, FaultyTransport, FunctionHandler,
                        MatchingEngine, MemoryRegion, PacketPool, Perm,
                        PostedOp, ResolvedResources, Runtime, Synchronizer,
                        IMMEDIATE_RCOMP_BITS, IMMEDIATE_TAG_BITS,
                        MAX_RCOMP_BITS, MAX_TAG_BITS, resolve_resources,
                        runtime, signal_error)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------
def _as_array(x: Any) -> Any:
    if isinstance(x, MemoryRegion):
        x.uses += 1
        return x.array
    return x


def _nbytes(x: Any) -> int:
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize if hasattr(
        x, "shape") else 0


def _resolve(op: FlexOp) -> ResolvedResources:
    """Resolve the resource set for a posting op from its optional
    ``.runtime(r)`` / ``.endpoint(ep)`` / ``.device(d)`` /
    ``.matching_engine(e)`` handles — one path for every op (endpoint →
    device → runtime defaults)."""
    opt = type(op)._optional
    res = resolve_resources(
        runtime=op.arg_or("runtime", None),
        endpoint=op.arg_or("endpoint", None),
        device=op.arg_or("device", None),
        engine=(op.arg_or("matching_engine", None)
                if "matching_engine" in opt else None),
        pool=op.arg_or("pool", None) if "pool" in opt else None)
    if res.endpoint is not None and op.arg_or("endpoint", None) is not None:
        res.endpoint.stats["posted"] += 1
    return res


def _default_comp(op: FlexOp) -> CompletionObject:
    comp = op.arg_or("comp", None)
    return comp if comp is not None else Synchronizer(threshold=1)


def _check_tag(tag: int, bits: int, what: str) -> None:
    if not (0 <= tag < (1 << bits)):
        raise ValueError(f"{what} {tag} out of range for {bits}-bit field")


@dataclasses.dataclass(eq=False)
class PostHandle:
    """Returned by every posting operation."""

    comp: CompletionObject
    posted: PostedOp

    def wait(self) -> List[Event]:
        if isinstance(self.comp, Synchronizer):
            return self.comp.wait()
        raise TypeError("wait() only on Synchronizer completions; poll the "
                        "completion queue / handler instead")

    def payload(self) -> Any:
        return self.wait()[0].payload

    @property
    def status(self) -> str:
        """Lifecycle state of the posted op: pending/matched/done or the
        terminal error-code value (cancelled/timeout/fatal/retry)."""
        return self.posted.state

    def cancel(self) -> bool:
        """Retire the op if it is still pending in its matching engine;
        signals a ``cancelled`` completion.  See :func:`cancel`."""
        return cancel(self)


# ---------------------------------------------------------------------------
# send / recv (two-sided, matched)
# ---------------------------------------------------------------------------
class send_x(FlexOp):
    """Post an asynchronous tagged send.

    ``send_x(buf).perm(Perm.shift(1)).tag(3).comp(cq).post()`` — any
    optional argument, any order; reusable.
    """

    _positional = ("buffer",)
    _optional = dict(perm=None, tag=0, comp=None, device=None,
                     matching_engine=None, runtime=None, endpoint=None,
                     ctx=None, allow_aggregation=True,
                     timeout=None, max_retries=0)

    def _invoke(self) -> PostHandle:
        buf = _as_array(self.arg("buffer"))
        res = _resolve(self)
        rt, dev, eng = res.runtime, res.device, res.engine
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        _check_tag(tag, MAX_TAG_BITS, "send tag")
        op = PostedOp(kind="send", buffer=buf, perm=self.arg_or("perm", None),
                      tag=tag, comp=comp, device=dev,
                      seq=rt.next_seq(),
                      context=self.arg_or("ctx", None), op_name="send",
                      allow_aggregation=self.arg_or("allow_aggregation", True),
                      timeout=self.arg_or("timeout", None),
                      max_retries=self.arg_or("max_retries", 0))
        dev.stats["posted"] += 1
        rt.watch_deadline(op)
        rt.enqueue_matches(eng.post(op))
        return PostHandle(comp=comp, posted=op)


class recv_x(FlexOp):
    """Post an asynchronous tagged receive.  ``like`` gives the shape and
    dtype of the incoming message (the LCI recv buffer)."""

    _positional = ("like",)
    _optional = dict(perm=None, tag=0, comp=None, device=None,
                     matching_engine=None, runtime=None, endpoint=None,
                     ctx=None, timeout=None, max_retries=0)

    def _invoke(self) -> PostHandle:
        like = self.arg("like")
        res = _resolve(self)
        rt, dev, eng = res.runtime, res.device, res.engine
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        _check_tag(tag, MAX_TAG_BITS, "recv tag")
        op = PostedOp(kind="recv", buffer=like,
                      perm=self.arg_or("perm", None), tag=tag, comp=comp,
                      device=dev, seq=rt.next_seq(),
                      context=self.arg_or("ctx", None), op_name="recv",
                      timeout=self.arg_or("timeout", None),
                      max_retries=self.arg_or("max_retries", 0))
        dev.stats["posted"] += 1
        rt.watch_deadline(op)
        rt.enqueue_matches(eng.post(op))
        return PostHandle(comp=comp, posted=op)


# ---------------------------------------------------------------------------
# put / get / active message (one-sided, unmatched)
# ---------------------------------------------------------------------------
class put_x(FlexOp):
    """One-sided RDMA-write analogue.  With ``remote_comp`` set it becomes
    *RDMA write with signal*; the immediate-data limits of the paper are
    enforced (16-bit tag, 15-bit remote handler) unless the device allows
    payload-carried metadata."""

    _positional = ("buffer",)
    _optional = dict(perm=None, tag=0, comp=None, remote_comp=None,
                     device=None, runtime=None, endpoint=None, ctx=None,
                     allow_aggregation=True, timeout=None, max_retries=0)

    _OP = "put"

    def _default_remote_comp(self, res: ResolvedResources
                             ) -> Optional[CompletionObject]:
        return None

    def _invoke(self) -> PostHandle:
        buf = _as_array(self.arg("buffer"))
        res = _resolve(self)
        rt, dev = res.runtime, res.device
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        rcomp = self.arg_or("remote_comp", None)
        if rcomp is None:
            rcomp = self._default_remote_comp(res)
        if isinstance(rcomp, int):
            rid, rcomp_obj = rcomp, rt.rcomp(rcomp)
        elif rcomp is not None:
            rid, rcomp_obj = rt.register_rcomp(rcomp), rcomp
        else:
            rid, rcomp_obj = 0, None
        if rcomp_obj is not None and self._OP == "put":
            # paper §2.2: put-with-remote-signal rides the 32-bit immediate
            # field: 16-bit tag + 15-bit remote handler.  Wider values fall
            # back to payload-carried metadata (extra memory references) if
            # the device permits.
            if (tag >= (1 << IMMEDIATE_TAG_BITS)
                    or rid >= (1 << IMMEDIATE_RCOMP_BITS)):
                if not dev.get_attr_allow_payload_metadata():
                    raise ValueError(
                        "put with remote signal: tag/remote-handler exceed "
                        f"the immediate-data limits ({IMMEDIATE_TAG_BITS}/"
                        f"{IMMEDIATE_RCOMP_BITS} bits) and payload-carried "
                        "metadata is disabled on this device")
                dev.stats["payload_metadata_msgs"] = (
                    dev.stats.get("payload_metadata_msgs", 0) + 1)
        _check_tag(tag, MAX_TAG_BITS, f"{self._OP} tag")
        if rid >= (1 << MAX_RCOMP_BITS):
            raise ValueError("remote completion handler id too wide")
        send = PostedOp(kind="send", buffer=buf,
                        perm=self.arg_or("perm", None), tag=tag, comp=comp,
                        device=dev, seq=rt.next_seq(),
                        context=self.arg_or("ctx", None), op_name=self._OP,
                        remote_comp=rcomp_obj,
                        allow_aggregation=self.arg_or(
                            "allow_aggregation", True),
                        state="matched",
                        timeout=self.arg_or("timeout", None),
                        max_retries=self.arg_or("max_retries", 0))
        recv = PostedOp(kind="recv", buffer=buf, perm=send.perm, tag=tag,
                        comp=rcomp_obj, device=dev, seq=send.seq,
                        context=self.arg_or("ctx", None), op_name=self._OP,
                        state="matched")
        dev.stats["posted"] += 1
        rt.watch_deadline(send)
        rt.enqueue_matches([(send, recv)])
        return PostHandle(comp=comp, posted=send)


class am_x(put_x):
    """Active message: payload transfer plus a *remote completion object of
    any type* (function handler, completion queue, synchronizer…) signalled
    at the destination (paper §2.2).  Defaults the remote completion to the
    resolved completion queue (endpoint's, then device's, then the
    runtime's default)."""

    _OP = "am"

    def _default_remote_comp(self, res: ResolvedResources
                             ) -> Optional[CompletionObject]:
        return res.cq


class get_x(FlexOp):
    """One-sided RDMA-read analogue: fetch ``like``-shaped data from the
    peer defined by ``perm`` (a src->dst pattern read *backwards*)."""

    _positional = ("like",)
    _optional = dict(perm=None, tag=0, comp=None, device=None, runtime=None,
                     endpoint=None, ctx=None, timeout=None, max_retries=0)

    def _invoke(self) -> PostHandle:
        like = _as_array(self.arg("like"))
        res = _resolve(self)
        rt, dev = res.runtime, res.device
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        _check_tag(tag, MAX_TAG_BITS, "get tag")
        perm = self.arg_or("perm", None)
        send = PostedOp(kind="send", buffer=like, perm=perm, tag=tag,
                        comp=None, device=dev, seq=rt.next_seq(),
                        context=self.arg_or("ctx", None), op_name="get",
                        state="matched",
                        timeout=self.arg_or("timeout", None),
                        max_retries=self.arg_or("max_retries", 0))
        recv = PostedOp(kind="recv", buffer=like, perm=perm, tag=tag,
                        comp=comp, device=dev, seq=send.seq,
                        context=self.arg_or("ctx", None), op_name="get",
                        state="matched")
        dev.stats["posted"] += 1
        rt.watch_deadline(send)
        rt.enqueue_matches([(send, recv)])
        return PostHandle(comp=comp, posted=recv)


# ---------------------------------------------------------------------------
# progress (explicit, user-driven)
# ---------------------------------------------------------------------------
class progress_x(FlexOp):
    """Materialize matched transfers and signal completion objects.

    The paper's explicit progress function: "allowing users to determine
    when and how frequently to invoke the communication progress engine."
    Trace-time meaning: *where* you call progress is where the transfers
    are placed in the program — the overlap knob.

    Returns the number of *actual transfers* materialized (an aggregated
    group is one transfer; loopback deliveries are zero), and
    ``max_transfers`` limits that same count — loopback groups never
    consume the budget.

    Fault path: each call advances the runtime's progress tick (the
    clock that op ``timeout`` deadlines and retry backoffs count in),
    releases due backoff re-posts, drains matches touching dead devices
    as ``fatal`` completions, routes live matches through the installed
    :class:`~repro.core.resources.FaultyTransport` (if any — resolved
    per match: explicit ``transport=`` > send device's > recv device's >
    runtime-wide fallback), and expires engine-pending ops past their
    deadline as ``timeout`` completions.

    Scoping: with no arguments, progresses the *global* runtime's entire
    ledger.  ``.runtime(rt)`` progresses another runtime; ``.device(d)``
    / ``.endpoint(ep)`` narrows to that device's ledger only (other
    devices' pending traffic is untouched — per-device progress
    isolation).
    """

    _positional = ()
    _optional = dict(device=None, pool=None, max_transfers=None,
                     transport=None, runtime=None, endpoint=None)

    def _invoke(self) -> int:
        explicit_dev = self.arg_or("device", None)
        ep = self.arg_or("endpoint", None)
        dev_filter = explicit_dev
        if dev_filter is None and ep is not None:
            dev_filter = ep.device
        rt = self.arg_or("runtime", None)
        if dev_filter is not None and dev_filter.migrated_to is not None:
            dev_filter = dev_filter.resolve_migrated()
        if rt is None and dev_filter is not None:
            rt = dev_filter.runtime
        if rt is None:
            rt = runtime()
        rt.tick += 1
        if rt.heartbeat is not None:
            # Heartbeats: every responsive device answers the progress
            # ping; a frozen device stays silent and the monitor's EMA
            # of inter-beat gaps eventually declares it dead (triggering
            # the configured failover/drain/raise policy).
            for d in rt.devices():
                if d.alive and d.responsive:
                    d.last_beat = rt.tick
            rt.heartbeat.poll(rt)
        pool = self.arg_or("pool", None)
        if pool is None and ep is not None:
            pool = ep.pool
        if pool is None and dev_filter is not None:
            pool = dev_filter.pool
        if pool is None:
            pool = rt.default_pool
        explicit_t = self.arg_or("transport", None)
        rt.release_retries()
        matches = rt.take_ready(dev_filter)
        n = 0
        if matches:
            live = []
            stalled = []
            for s, r in matches:
                if not (s.device.alive and r.device.alive):
                    signal_error(s, r, ErrorCode.FATAL)
                elif not (s.device.responsive and r.device.responsive):
                    # frozen (silently dead) device: its transfers stall
                    # in the ledger until a heartbeat monitor declares it
                    # dead and fails them over (or drains them fatal)
                    stalled.append((s, r))
                else:
                    live.append((s, r))
            if stalled:
                rt.enqueue_matches(stalled)
            live.sort(key=lambda m: m[0].seq)
            if explicit_t is not None:
                live = explicit_t.apply(live, rt)
            else:
                # Per-device transports: resolve and apply per match in
                # global seq order so a shared transport's seeded RNG
                # consumes draws exactly as a single global one would.
                routed: List[Tuple[PostedOp, PostedOp]] = []
                for s, r in live:
                    t = s.device.transport or r.device.transport \
                        or rt.transport
                    if t is None:
                        routed.append((s, r))
                    else:
                        routed.extend(t.apply([(s, r)], rt))
                live = routed
            if live:
                limit = self.arg_or("max_transfers", None)
                n = _execute(rt, live, pool, limit)
            if dev_filter is not None:
                dev_filter.stats["progressed"] += 1
        rt.expire_timeouts()
        return n


def _pack_class(dtype: Any) -> str:
    """Aggregation packing class.  Bitcast-safe dtypes share one byte-view
    class so mixed-dtype eager messages on the same perm ride one
    transfer; bools (no uint8 bitcast) aggregate only among themselves."""
    dt = jnp.dtype(dtype)
    if dt.kind == "b":
        return f"dtype:{dt.name}"
    return "bytes"


def _execute(rt: Runtime, matches: List[Tuple[PostedOp, PostedOp]],
             pool: Optional[PacketPool], limit: Optional[int]) -> int:
    """Group, aggregate, and run matched transfers.

    Message stats (``eager_msgs``/``rendezvous_msgs``) are bumped only
    for groups actually *executed* this call — matches re-enqueued by the
    ``max_transfers`` budget are counted when they finally run, not on
    every progress attempt.
    """
    groups: Dict[Any, List[Tuple[PostedOp, PostedOp]]] = {}
    for s, r in matches:
        axis = s.device.axis
        if (pool is not None and pool.get_attr_aggregate()
                and s.allow_aggregation and s.fault_mark is None
                and axis is not None
                and pool.is_eager(_nbytes(s.buffer))):
            pkey = s.perm.key(s.device.axis_size) if s.perm else ()
            key = ("agg", axis, pkey, id(s.device),
                   _pack_class(s.buffer.dtype))
        else:
            key = ("solo", id(s))
        groups.setdefault(key, []).append((s, r))

    n_transfers = 0
    for key, grp in groups.items():
        cost = 0 if grp[0][0].device.axis is None else 1
        if limit is not None and cost and n_transfers + cost > limit:
            # out of transfer budget — leave the group pending
            rt.enqueue_matches(grp)
            continue
        if key[0] == "agg":
            if pool is not None:
                pool.stats["eager_msgs"] += len(grp)
            if len(grp) > 1:
                _run_aggregated(rt, grp, pool)
            else:
                _run_single(rt, *grp[0])
        else:
            for s, r in grp:
                _run_single(rt, s, r)
                if pool is not None and s.device.axis is not None:
                    pool.stats["rendezvous_msgs"] += 1
                    pool.stats["raw_transfers"] += 1
        n_transfers += cost
    return n_transfers


def _permute(value: Any, dev: Device, perm: Optional[Perm]) -> Any:
    axis = dev.axis
    if axis is None:  # loopback / sim device
        return value
    pairs = perm.pairs_for(dev.axis_size) if perm else [
        (i, i) for i in range(dev.axis_size)]
    dev.stats["transfers"] += 1
    dev.stats["bytes_moved"] += _nbytes(value)
    return lax.ppermute(value, axis_name=axis, perm=pairs)


def _check_shapes(s: PostedOp, r: PostedOp) -> None:
    if getattr(r.buffer, "shape", None) is not None and hasattr(
            s.buffer, "shape"):
        if tuple(r.buffer.shape) != tuple(s.buffer.shape):
            raise ValueError(
                f"matched send/recv shape mismatch: send {s.buffer.shape} "
                f"vs recv {r.buffer.shape} (tag={s.tag})")


def _corrupt_value(x: Any) -> Any:
    """Deterministic payload corruption: bitwise inversion through a
    uint8 view (bools, which have no byte bitcast, flip logically)."""
    dt = jnp.dtype(x.dtype)
    if dt.kind == "b":
        return jnp.logical_not(x)
    b = lax.bitcast_convert_type(x, jnp.uint8)
    return lax.bitcast_convert_type(jnp.bitwise_not(b), dt)


def _run_single(rt: Runtime, s: PostedOp, r: PostedOp) -> None:
    value = _permute(s.buffer, s.device, s.perm)
    _check_shapes(s, r)
    _signal(rt, s, r, value)


@dataclasses.dataclass(eq=False)
class AggPlan:
    """A cached concat/slice layout for one aggregated transfer: how to
    pack N eager messages into one flat buffer and carve the arrival back
    into per-message payloads.  Keyed by (axis, perm-key, dtype-signature,
    shape-signature), so steady-state progress loops (pipeline ticks,
    serving decode steps) reuse the plan instead of re-deriving it."""

    mixed: bool                      # byte-view packing (mixed dtypes)?
    sizes: Tuple[int, ...]           # flat length per message (elems/bytes)
    offsets: Tuple[int, ...]         # start offset per message
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    itemsizes: Tuple[int, ...]


def _agg_plan(rt: Runtime, grp: List[Tuple[PostedOp, PostedOp]]) -> AggPlan:
    """Look up or build the aggregation plan for a seq-sorted group."""
    s0 = grp[0][0]
    dtypes = tuple(jnp.dtype(s.buffer.dtype) for s, _ in grp)
    shapes = tuple(tuple(s.buffer.shape) for s, _ in grp)
    pkey = s0.perm.key(s0.device.axis_size) if s0.perm else ()
    sig = (s0.device.axis, pkey, tuple(d.name for d in dtypes), shapes)
    cache = rt.agg_plans
    plan = cache.get(sig)
    if plan is not None:
        rt.plan_stats["hits"] += 1
        return plan
    rt.plan_stats["misses"] += 1
    mixed = len(set(dtypes)) > 1
    itemsizes = tuple(d.itemsize for d in dtypes)
    if mixed:
        sizes = tuple(int(np.prod(sh, dtype=np.int64)) * isz
                      for sh, isz in zip(shapes, itemsizes))
    else:
        sizes = tuple(int(np.prod(sh, dtype=np.int64)) for sh in shapes)
    offsets, off = [], 0
    for sz in sizes:
        offsets.append(off)
        off += sz
    plan = AggPlan(mixed=mixed, sizes=sizes, offsets=tuple(offsets),
                   shapes=shapes, dtypes=dtypes, itemsizes=itemsizes)
    if len(cache) >= 4096:           # bound steady-state memory
        cache.clear()
    cache[sig] = plan
    return plan


def _byte_view(x: Any) -> Any:
    """Flat uint8 view of an array (bitcast appends an itemsize-wide
    trailing dim for multi-byte dtypes; ravel flattens it away)."""
    return jnp.ravel(lax.bitcast_convert_type(x, jnp.uint8))


def _run_aggregated(rt: Runtime, grp: List[Tuple[PostedOp, PostedOp]],
                    pool: Optional[PacketPool]) -> None:
    """Pack eager messages sharing (axis, perm) into one transfer.

    Same-dtype groups concatenate directly; mixed-dtype groups ride a
    byte view (uint8 bitcast) so one packed transfer still suffices.
    """
    grp = sorted(grp, key=lambda m: m[0].seq)
    for s, r in grp:
        _check_shapes(s, r)
    plan = _agg_plan(rt, grp)
    if plan.mixed:
        flats = [_byte_view(s.buffer) for s, _ in grp]
    else:
        flats = [jnp.ravel(s.buffer) for s, _ in grp]
    packed = jnp.concatenate(flats, axis=0)
    out = _permute(packed, grp[0][0].device, grp[0][0].perm)
    if pool is not None:
        pool.stats["aggregated_transfers"] += 1
    for (s, r), off, sz, shape, dt, isz in zip(
            grp, plan.offsets, plan.sizes, plan.shapes, plan.dtypes,
            plan.itemsizes):
        piece = lax.dynamic_slice_in_dim(out, off, sz, axis=0)
        if plan.mixed:
            if isz > 1:
                piece = piece.reshape(sz // isz, isz)
            piece = lax.bitcast_convert_type(piece, dt)
        _signal(rt, s, r, piece.reshape(shape))


def _signal(rt: Runtime, s: PostedOp, r: PostedOp, value: Any) -> None:
    """Deliver completions for an executed transfer.

    The receiver is signalled first: a full completion queue returns
    ``retry`` instead of raising from inside progress, and that
    backpressure decides what the poster sees — an automatic backoff
    re-post when the op has retry budget, else a ``retry``-status
    completion the poster can re-post on.  The transport's per-hop
    ``fault_mark`` (duplicate / corrupt) is consumed here.

    Migrated (failed-over) transfers are exactly-once: each absorbed
    delivery records the op's seq in the runtime's dedup window, and a
    *migrated* replay whose seq already delivered is suppressed instead
    of double-delivered.  Transport-injected duplicates are exempt (the
    link duplicated the packet; both copies arrive, as on real wires).
    """
    mark, s.fault_mark = s.fault_mark, None
    migrated = s.migrated or r.migrated
    r_status = ErrorCode.OK
    if mark in ("corrupt", "corrupt_silent"):
        value = _corrupt_value(value)
        if mark == "corrupt":
            r_status = ErrorCode.RETRY
    if migrated and rt.was_delivered(s.seq):
        # the transfer raced the failure: it was already delivered before
        # the device died, and the failover replayed it — suppress.
        rt.failover_stats["dedup_suppressed"] += 1
        already_done = s.state == "done"
        s.state = r.state = "done"
        if s.comp is not None and not already_done:
            s.comp.signal(Event(payload=None, op=s.op_name, tag=s.tag,
                                perm=s.perm, remote=False, context=s.context,
                                migrated=True))
        return
    if r.comp is not None:
        remote = s.op_name in ("put", "am")
        ret = r.comp.signal(Event(payload=value, op=s.op_name, tag=r.tag,
                                  perm=r.perm, remote=remote,
                                  context=r.context, status=r_status,
                                  migrated=migrated))
        if ret is ErrorCode.RETRY and r_status.ok:
            # completion-queue overflow: the delivery was not absorbed
            if rt.schedule_retry(s, r):
                return                    # re-delivered after backoff
            s.state = r.state = "retry"
            if s.comp is not None:
                s.comp.signal(Event(payload=None, op=s.op_name, tag=s.tag,
                                    perm=s.perm, remote=False,
                                    context=s.context,
                                    status=ErrorCode.RETRY,
                                    migrated=migrated))
            return
        rt.note_delivered(s.seq)
        if mark == "duplicate":
            r.comp.signal(Event(payload=value, op=s.op_name, tag=r.tag,
                                perm=r.perm, remote=remote,
                                context=r.context, status=r_status,
                                migrated=migrated))
    else:
        rt.note_delivered(s.seq)
    s.state = r.state = "done"
    if s.comp is not None:
        s.comp.signal(Event(payload=None, op=s.op_name, tag=s.tag,
                            perm=s.perm, remote=False, context=s.context,
                            migrated=migrated))


# ---------------------------------------------------------------------------
# Convenience composites
# ---------------------------------------------------------------------------
def sendrecv(buffer: Any, perm: Perm, tag: int = 0,
             device: Optional[Device] = None,
             matching_engine: Optional[MatchingEngine] = None,
             runtime: Optional[Runtime] = None,
             endpoint: Optional[Endpoint] = None) -> Any:
    """Matched shift: send along ``perm`` and receive the inbound message.
    Posts both sides, progresses, returns the received array."""
    sync = Synchronizer(threshold=2)
    send_x(buffer).perm(perm).tag(tag).comp(sync).device(device) \
        .matching_engine(matching_engine).runtime(runtime) \
        .endpoint(endpoint)()
    recv_x(buffer).perm(perm).tag(tag).comp(sync).device(device) \
        .matching_engine(matching_engine).runtime(runtime) \
        .endpoint(endpoint)()
    progress_x().runtime(runtime).device(device).endpoint(endpoint)()
    events = sync.wait()
    (payload,) = [e.payload for e in events if e.payload is not None]
    return payload


def cancel(handle: Any) -> bool:
    """Cancel a posted-but-unmatched operation.

    Accepts a :class:`PostHandle` or a raw
    :class:`~repro.core.resources.PostedOp`.  If the op is still pending
    in its matching engine it is retired from the keyed buckets, its
    completion object receives a ``cancelled``-status event, and the
    call returns True.  Ops that already matched (their transfer is in
    the ledger or executed) return False — too late to cancel.
    """
    op = handle.posted if isinstance(handle, PostHandle) else handle
    if not isinstance(op, PostedOp):
        raise TypeError(f"cancel() takes a PostHandle or PostedOp, "
                        f"got {type(op).__name__}")
    if op.state != "pending" or op.engine is None:
        return False
    if not op.engine.cancel(op):
        return False
    op.state = "cancelled"
    if op.comp is not None:
        op.comp.signal(Event(payload=None, op=op.op_name, tag=op.tag,
                             perm=op.perm, remote=False, context=op.context,
                             status=ErrorCode.CANCELLED))
    return True


def register_memory(array: Any,
                    runtime_: Optional[Runtime] = None) -> MemoryRegion:
    rt = runtime_ if runtime_ is not None else runtime()
    return rt.register_memory(array)


def register_rcomp(comp: CompletionObject,
                   runtime_: Optional[Runtime] = None) -> int:
    rt = runtime_ if runtime_ is not None else runtime()
    return rt.register_rcomp(comp)


# Plain-function shorthands (binding guideline).
send = plain(send_x)
recv = plain(recv_x)
put = plain(put_x)
get = plain(get_x)
am = plain(am_x)
progress = plain(progress_x)
