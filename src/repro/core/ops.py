"""LCX communication-posting operations (paper §2.2) as objectized
flexible functions (paper §3.1).

All posting operations are **asynchronous**: they pend the operation and
return a :class:`PostHandle`.  Completion is observed through the
completion object passed via ``.comp(...)`` (or an auto-allocated
:class:`~repro.core.resources.Synchronizer`) *after* an explicit
:func:`progress` call — the paper's explicit-progress design point.

Naming follows the binding guideline: flexible form ``send_x``, plain
shorthand ``send`` with positional arguments only.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .flex import FlexOp, plain
from .resources import (CompletionObject, CompletionQueue, Device, Event,
                        FunctionHandler, MatchingEngine, MemoryRegion,
                        PacketPool, Perm, PostedOp, Synchronizer,
                        IMMEDIATE_RCOMP_BITS, IMMEDIATE_TAG_BITS,
                        MAX_RCOMP_BITS, MAX_TAG_BITS, runtime)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------
def _as_array(x: Any) -> Any:
    if isinstance(x, MemoryRegion):
        x.uses += 1
        return x.array
    return x


def _nbytes(x: Any) -> int:
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize if hasattr(
        x, "shape") else 0


def _default_device(op: FlexOp) -> Device:
    dev = op.arg_or("device", None)
    return dev if dev is not None else runtime().default_device


def _default_engine(op: FlexOp) -> MatchingEngine:
    eng = op.arg_or("matching_engine", None)
    return eng if eng is not None else runtime().default_engine


def _default_comp(op: FlexOp) -> CompletionObject:
    comp = op.arg_or("comp", None)
    return comp if comp is not None else Synchronizer(threshold=1)


def _check_tag(tag: int, bits: int, what: str) -> None:
    if not (0 <= tag < (1 << bits)):
        raise ValueError(f"{what} {tag} out of range for {bits}-bit field")


@dataclasses.dataclass(eq=False)
class PostHandle:
    """Returned by every posting operation."""

    comp: CompletionObject
    posted: PostedOp

    def wait(self) -> List[Event]:
        if isinstance(self.comp, Synchronizer):
            return self.comp.wait()
        raise TypeError("wait() only on Synchronizer completions; poll the "
                        "completion queue / handler instead")

    def payload(self) -> Any:
        return self.wait()[0].payload


# ---------------------------------------------------------------------------
# send / recv (two-sided, matched)
# ---------------------------------------------------------------------------
class send_x(FlexOp):
    """Post an asynchronous tagged send.

    ``send_x(buf).perm(Perm.shift(1)).tag(3).comp(cq).post()`` — any
    optional argument, any order; reusable.
    """

    _positional = ("buffer",)
    _optional = dict(perm=None, tag=0, comp=None, device=None,
                     matching_engine=None, ctx=None, allow_aggregation=True)

    def _invoke(self) -> PostHandle:
        buf = _as_array(self.arg("buffer"))
        dev = _default_device(self)
        eng = _default_engine(self)
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        _check_tag(tag, MAX_TAG_BITS, "send tag")
        op = PostedOp(kind="send", buffer=buf, perm=self.arg_or("perm", None),
                      tag=tag, comp=comp, device=dev,
                      seq=runtime().next_seq(),
                      context=self.arg_or("ctx", None), op_name="send",
                      allow_aggregation=self.arg_or("allow_aggregation", True))
        dev.stats["posted"] += 1
        runtime().enqueue_matches(eng.post(op))
        return PostHandle(comp=comp, posted=op)


class recv_x(FlexOp):
    """Post an asynchronous tagged receive.  ``like`` gives the shape and
    dtype of the incoming message (the LCI recv buffer)."""

    _positional = ("like",)
    _optional = dict(perm=None, tag=0, comp=None, device=None,
                     matching_engine=None, ctx=None)

    def _invoke(self) -> PostHandle:
        like = self.arg("like")
        dev = _default_device(self)
        eng = _default_engine(self)
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        _check_tag(tag, MAX_TAG_BITS, "recv tag")
        op = PostedOp(kind="recv", buffer=like,
                      perm=self.arg_or("perm", None), tag=tag, comp=comp,
                      device=dev, seq=runtime().next_seq(),
                      context=self.arg_or("ctx", None), op_name="recv")
        dev.stats["posted"] += 1
        runtime().enqueue_matches(eng.post(op))
        return PostHandle(comp=comp, posted=op)


# ---------------------------------------------------------------------------
# put / get / active message (one-sided, unmatched)
# ---------------------------------------------------------------------------
class put_x(FlexOp):
    """One-sided RDMA-write analogue.  With ``remote_comp`` set it becomes
    *RDMA write with signal*; the immediate-data limits of the paper are
    enforced (16-bit tag, 15-bit remote handler) unless the device allows
    payload-carried metadata."""

    _positional = ("buffer",)
    _optional = dict(perm=None, tag=0, comp=None, remote_comp=None,
                     device=None, ctx=None, allow_aggregation=True)

    _OP = "put"

    def _invoke(self) -> PostHandle:
        buf = _as_array(self.arg("buffer"))
        dev = _default_device(self)
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        rcomp = self.arg_or("remote_comp", None)
        if isinstance(rcomp, int):
            rid, rcomp_obj = rcomp, runtime().rcomp(rcomp)
        elif rcomp is not None:
            rid, rcomp_obj = runtime().register_rcomp(rcomp), rcomp
        else:
            rid, rcomp_obj = 0, None
        if rcomp_obj is not None and self._OP == "put":
            # paper §2.2: put-with-remote-signal rides the 32-bit immediate
            # field: 16-bit tag + 15-bit remote handler.  Wider values fall
            # back to payload-carried metadata (extra memory references) if
            # the device permits.
            if (tag >= (1 << IMMEDIATE_TAG_BITS)
                    or rid >= (1 << IMMEDIATE_RCOMP_BITS)):
                if not dev.get_attr_allow_payload_metadata():
                    raise ValueError(
                        "put with remote signal: tag/remote-handler exceed "
                        f"the immediate-data limits ({IMMEDIATE_TAG_BITS}/"
                        f"{IMMEDIATE_RCOMP_BITS} bits) and payload-carried "
                        "metadata is disabled on this device")
                dev.stats["payload_metadata_msgs"] = (
                    dev.stats.get("payload_metadata_msgs", 0) + 1)
        _check_tag(tag, MAX_TAG_BITS, f"{self._OP} tag")
        if rid >= (1 << MAX_RCOMP_BITS):
            raise ValueError("remote completion handler id too wide")
        send = PostedOp(kind="send", buffer=buf,
                        perm=self.arg_or("perm", None), tag=tag, comp=comp,
                        device=dev, seq=runtime().next_seq(),
                        context=self.arg_or("ctx", None), op_name=self._OP,
                        remote_comp=rcomp_obj,
                        allow_aggregation=self.arg_or(
                            "allow_aggregation", True))
        recv = PostedOp(kind="recv", buffer=buf, perm=send.perm, tag=tag,
                        comp=rcomp_obj, device=dev, seq=send.seq,
                        context=self.arg_or("ctx", None), op_name=self._OP)
        dev.stats["posted"] += 1
        runtime().enqueue_matches([(send, recv)])
        return PostHandle(comp=comp, posted=send)


class am_x(put_x):
    """Active message: payload transfer plus a *remote completion object of
    any type* (function handler, completion queue, synchronizer…) signalled
    at the destination (paper §2.2).  Defaults the remote completion to the
    runtime's default completion queue."""

    _OP = "am"

    def _invoke(self) -> PostHandle:
        if self.arg_or("remote_comp", None) is None:
            self._args["remote_comp"] = runtime().default_cq
        return super()._invoke()


class get_x(FlexOp):
    """One-sided RDMA-read analogue: fetch ``like``-shaped data from the
    peer defined by ``perm`` (a src->dst pattern read *backwards*)."""

    _positional = ("like",)
    _optional = dict(perm=None, tag=0, comp=None, device=None, ctx=None)

    def _invoke(self) -> PostHandle:
        like = _as_array(self.arg("like"))
        dev = _default_device(self)
        comp = _default_comp(self)
        tag = self.arg_or("tag", 0)
        _check_tag(tag, MAX_TAG_BITS, "get tag")
        perm = self.arg_or("perm", None)
        send = PostedOp(kind="send", buffer=like, perm=perm, tag=tag,
                        comp=None, device=dev, seq=runtime().next_seq(),
                        context=self.arg_or("ctx", None), op_name="get")
        recv = PostedOp(kind="recv", buffer=like, perm=perm, tag=tag,
                        comp=comp, device=dev, seq=send.seq,
                        context=self.arg_or("ctx", None), op_name="get")
        dev.stats["posted"] += 1
        runtime().enqueue_matches([(send, recv)])
        return PostHandle(comp=comp, posted=recv)


# ---------------------------------------------------------------------------
# progress (explicit, user-driven)
# ---------------------------------------------------------------------------
class progress_x(FlexOp):
    """Materialize matched transfers and signal completion objects.

    The paper's explicit progress function: "allowing users to determine
    when and how frequently to invoke the communication progress engine."
    Trace-time meaning: *where* you call progress is where the transfers
    are placed in the program — the overlap knob.
    """

    _positional = ()
    _optional = dict(device=None, pool=None, max_transfers=None)

    def _invoke(self) -> int:
        dev_filter = self.arg_or("device", None)
        pool = self.arg_or("pool", None) or runtime().default_pool
        matches = runtime().take_ready(dev_filter)
        if not matches:
            return 0
        matches.sort(key=lambda m: m[0].seq)
        limit = self.arg_or("max_transfers", None)
        n = _execute(matches, pool, limit)
        if dev_filter is not None:
            dev_filter.stats["progressed"] += 1
        return n


def _execute(matches: List[Tuple[PostedOp, PostedOp]],
             pool: Optional[PacketPool], limit: Optional[int]) -> int:
    """Group, aggregate, and run matched transfers."""
    groups: Dict[Any, List[Tuple[PostedOp, PostedOp]]] = {}
    for s, r in matches:
        axis = s.device.axis
        if (pool is not None and pool.get_attr_aggregate()
                and s.allow_aggregation and axis is not None
                and pool.is_eager(_nbytes(s.buffer))):
            pkey = s.perm.key(s.device.axis_size) if s.perm else ()
            key = ("agg", axis, pkey, jnp.dtype(s.buffer.dtype).name,
                   id(s.device))
            if pool is not None:
                pool.stats["eager_msgs"] += 1
        else:
            key = ("solo", id(s))
            if pool is not None and axis is not None:
                pool.stats["rendezvous_msgs"] += 1
        groups.setdefault(key, []).append((s, r))

    n_transfers = 0
    for key, grp in groups.items():
        if limit is not None and n_transfers >= limit:
            # leave the rest pending
            runtime().enqueue_matches(grp)
            continue
        if key[0] == "agg" and len(grp) > 1:
            _run_aggregated(grp, pool)
        else:
            for s, r in grp:
                _run_single(s, r)
                if pool is not None and key[0] == "solo":
                    pool.stats["raw_transfers"] += 1
        n_transfers += 1
    return n_transfers


def _permute(value: Any, dev: Device, perm: Optional[Perm]) -> Any:
    axis = dev.axis
    if axis is None:  # loopback / sim device
        return value
    pairs = perm.pairs_for(dev.axis_size) if perm else [
        (i, i) for i in range(dev.axis_size)]
    dev.stats["transfers"] += 1
    dev.stats["bytes_moved"] += _nbytes(value)
    return lax.ppermute(value, axis_name=axis, perm=pairs)


def _run_single(s: PostedOp, r: PostedOp) -> None:
    value = _permute(s.buffer, s.device, s.perm)
    if getattr(r.buffer, "shape", None) is not None and hasattr(
            s.buffer, "shape"):
        if tuple(r.buffer.shape) != tuple(s.buffer.shape):
            raise ValueError(
                f"matched send/recv shape mismatch: send {s.buffer.shape} "
                f"vs recv {r.buffer.shape} (tag={s.tag})")
    _signal(s, r, value)


def _run_aggregated(grp: List[Tuple[PostedOp, PostedOp]],
                    pool: Optional[PacketPool]) -> None:
    """Pack eager messages sharing (axis, perm, dtype) into one transfer."""
    grp = sorted(grp, key=lambda m: m[0].seq)
    flats = [jnp.ravel(s.buffer) for s, _ in grp]
    sizes = [f.shape[0] for f in flats]
    packed = jnp.concatenate(flats, axis=0)
    out = _permute(packed, grp[0][0].device, grp[0][0].perm)
    if pool is not None:
        pool.stats["aggregated_transfers"] += 1
    off = 0
    for (s, r), sz in zip(grp, sizes):
        piece = lax.dynamic_slice_in_dim(out, off, sz, axis=0)
        off += sz
        _signal(s, r, piece.reshape(s.buffer.shape))


def _signal(s: PostedOp, r: PostedOp, value: Any) -> None:
    if s.comp is not None:
        s.comp.signal(Event(payload=None, op=s.op_name, tag=s.tag,
                            perm=s.perm, remote=False, context=s.context))
    if r.comp is not None:
        remote = s.op_name in ("put", "am")
        r.comp.signal(Event(payload=value, op=s.op_name, tag=r.tag,
                            perm=r.perm, remote=remote, context=r.context))


# ---------------------------------------------------------------------------
# Convenience composites
# ---------------------------------------------------------------------------
def sendrecv(buffer: Any, perm: Perm, tag: int = 0,
             device: Optional[Device] = None,
             matching_engine: Optional[MatchingEngine] = None) -> Any:
    """Matched shift: send along ``perm`` and receive the inbound message.
    Posts both sides, progresses, returns the received array."""
    sync = Synchronizer(threshold=2)
    send_x(buffer).perm(perm).tag(tag).comp(sync).device(device) \
        .matching_engine(matching_engine)()
    recv_x(buffer).perm(perm).tag(tag).comp(sync).device(device) \
        .matching_engine(matching_engine)()
    progress_x()()
    events = sync.wait()
    (payload,) = [e.payload for e in events if e.payload is not None]
    return payload


def register_memory(array: Any) -> MemoryRegion:
    return runtime().register_memory(array)


def register_rcomp(comp: CompletionObject) -> int:
    return runtime().register_rcomp(comp)


# Plain-function shorthands (binding guideline).
send = plain(send_x)
recv = plain(recv_x)
put = plain(put_x)
get = plain(get_x)
am = plain(am_x)
progress = plain(progress_x)
