"""LCX resources (paper §2.2).

The interface consists of *resources* and *operations*, arranged in the
paper's explicit hierarchy::

    Runtime → NetContext → Device → Endpoint

Every level is independently constructible and carries (or resolves to)
its own matching engine, packet pool, and default completion resources;
the process-global :func:`runtime` is merely a lazily created *default*
instance (the paper's ``g_runtime`` idiom), not the only one.  Two
runtimes — or two isolated devices on one runtime — can coexist in one
process with independent ``pending()`` accounting, fault injection, and
``finalize()`` leak checks.  See ``docs/resources.md``.

Major resources:

- :class:`Runtime` — top of the hierarchy: default resources, the
  pending-transfer ledger, sequence/registry state, fault clocks.
- :class:`NetContext` — one per network backend ("xla" / "pallas" /
  "sim"); owns devices.
- :class:`Device` — encapsulates the low-level network resource.  On TPU
  the "network" is the ICI mesh accessed through compiled collectives;
  a Device names a mesh axis (its communicator) plus a backend and
  tunable attributes.  Hierarchy-created devices own a private matching
  engine, packet pool, and completion queue (library/thread isolation);
  bare ``Device(...)`` stays *floating* and shares the ambient runtime's
  defaults, preserving the legacy single-pool behaviour.
- :class:`Endpoint` — the posting resource on a device (one per thread
  or library); may override the device's engine/pool/completion queue.
- :class:`PacketPool` — pre-registered fixed-size internal buffers.  At
  the JAX level the pool enables *message aggregation*: many fine-grained
  eager-protocol messages are packed into one transfer (the TPU analogue
  of doorbell batching / packet reuse).
- :class:`MatchingEngine` — matches sends with receives.  Two
  implementations (``queue`` in-order, ``map`` keyed) and five policies
  (``none``, ``rank_only``, ``tag_only``, ``rank_tag``, ``custom``).
- Completion objects — :class:`Synchronizer`, :class:`CompletionQueue`,
  :class:`FunctionHandler`; all subclassable via ``signal()``.

Resources map to operations independently: two operations may share a
device but use different completion objects; sends and recvs posted on
*different devices* still match if they share a matching engine.

Execution model (hardware adaptation, see DESIGN.md §2): LCI posts
operations at *runtime* from many threads; LCX posts at *trace time*
inside one SPMD program.  Posted operations are pended; the
:func:`~repro.core.ops.progress` operation resolves matches and
materializes transfers as ``lax.ppermute``/``lax.all_to_all`` ops (or
Pallas remote-DMA kernels), then signals completion objects.  Completion
is data availability of the traced value.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import os
import random
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attr import HasAttrs

# Interface constants (paper §2.2): immediate-data-constrained limits for
# put-with-remote-signal; full-width limits elsewhere.
IMMEDIATE_TAG_BITS = 16
IMMEDIATE_RCOMP_BITS = 15
MAX_TAG_BITS = 64
MAX_RCOMP_BITS = 32


# ---------------------------------------------------------------------------
# Permutation specs (who talks to whom on a device's axis)
# ---------------------------------------------------------------------------
class Perm:
    """A trace-time communication pattern on a device axis.

    In SPMD there is no runtime "destination rank" argument; the pattern
    *is* the argument.  ``Perm.shift(1)`` is the ring successor,
    ``Perm.pairs([(0, 3)])`` a single point-to-point message (other ranks
    carry padding), ``Perm.all_to(r)``/``Perm.from_(r)`` fan-in/fan-out.
    """

    def __init__(self, fn: Callable[[int], List[Tuple[int, int]]], name: str):
        self._fn = fn
        self.name = name
        # Per-axis_size memo: the progress engine re-derives pairs/keys on
        # every post and every transfer, so these are hot-path lookups.
        self._pairs_memo: Dict[int, List[Tuple[int, int]]] = {}
        self._key_memo: Dict[int, Tuple[Tuple[int, int], ...]] = {}

    def pairs_for(self, axis_size: int) -> List[Tuple[int, int]]:
        pairs = self._pairs_memo.get(axis_size)
        if pairs is None:
            pairs = self._pairs_memo[axis_size] = self._fn(axis_size)
        return pairs

    # -- constructors -------------------------------------------------------
    @staticmethod
    def shift(k: int) -> "Perm":
        return Perm(lambda n: [(i, (i + k) % n) for i in range(n)],
                    f"shift({k})")

    @staticmethod
    def pairs(ps: Sequence[Tuple[int, int]]) -> "Perm":
        ps = [tuple(p) for p in ps]
        return Perm(lambda n: list(ps), f"pairs({ps})")

    @staticmethod
    def to(dst: int, src: int) -> "Perm":
        return Perm.pairs([(src, dst)])

    def key(self, axis_size: int) -> Tuple[Tuple[int, int], ...]:
        key = self._key_memo.get(axis_size)
        if key is None:
            key = self._key_memo[axis_size] = tuple(
                sorted(self.pairs_for(axis_size)))
        return key

    def inverse(self) -> "Perm":
        fn = self._fn
        return Perm(lambda n: [(d, s) for (s, d) in fn(n)],
                    f"inv({self.name})")

    def __repr__(self) -> str:
        return f"Perm<{self.name}>"


# ---------------------------------------------------------------------------
# Status codes (LCI errorcode_t analogue)
# ---------------------------------------------------------------------------
class ErrorCode(enum.Enum):
    """Per-operation status, mirroring LCI's ``errorcode_t``: every post
    and every completion carries one instead of success-or-crash.

    - ``OK``        — the operation completed normally.
    - ``RETRY``     — transient resource exhaustion (completion-queue
      overflow, corrupt-marked delivery); the poster may re-post.
    - ``TIMEOUT``   — the op's progress-call-count deadline elapsed
      before a match/delivery.
    - ``CANCELLED`` — the op was retired via :func:`repro.core.cancel`.
    - ``FATAL``     — unrecoverable (retries exhausted, dead device).
    """

    OK = "ok"
    RETRY = "retry"
    TIMEOUT = "timeout"
    CANCELLED = "cancelled"
    FATAL = "fatal"

    @property
    def ok(self) -> bool:
        return self is ErrorCode.OK


class CompletionError(RuntimeError):
    """Raised when a waited-on completion carries a non-ok status.
    ``events`` holds the offending :class:`Event` objects."""

    def __init__(self, msg: str, events: Sequence["Event"] = ()) -> None:
        super().__init__(msg)
        self.events = list(events)


# ---------------------------------------------------------------------------
# Completion objects
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class Event:
    """A completion event delivered to a completion object."""

    payload: Any = None          # traced array (recv/get/am/put-target side)
    op: str = ""                 # "send"|"recv"|"put"|"get"|"am"
    tag: int = 0
    perm: Optional[Perm] = None
    remote: bool = False         # True when this is a *remote* completion
    context: Any = None          # user context passed at post time
    status: ErrorCode = ErrorCode.OK
    # True when the op travelled through a device failover: either it
    # replayed on the survivor (status ok) or it needs a re-post there
    # (status retry).  Consumers (AMT executor) use this to re-dispatch
    # instead of dead-lettering.
    migrated: bool = False


class CompletionObject(HasAttrs):
    """Base completion object.  Users may subclass and override
    :meth:`signal` to customize completion semantics (paper: e.g. an
    atomic-counter object waiting for all previously posted ops)."""

    _ATTR_DEFAULTS: Dict[str, Any] = {}

    def __init__(self, **attrs: Any) -> None:
        self._init_attrs(attrs)

    def signal(self, event: Event) -> Optional[ErrorCode]:
        """Deliver one event.  May return :attr:`ErrorCode.RETRY` to
        push back on the signaller (e.g. queue overflow); ``None`` or
        :attr:`ErrorCode.OK` mean the event was absorbed."""
        raise NotImplementedError  # pragma: no cover - abstract

    # Default-resource bookkeeping
    def __repr__(self) -> str:
        return f"{type(self).__name__}@{id(self):x}"


class Synchronizer(CompletionObject):
    """MPI-request-like object that can wait for *multiple* completed
    operations before becoming ready (paper §2.2)."""

    _ATTR_DEFAULTS = {"threshold": 1}

    def __init__(self, threshold: Optional[int] = None, **attrs: Any) -> None:
        super().__init__(threshold=threshold, **attrs)
        self._events: List[Event] = []

    def signal(self, event: Event) -> None:
        self._events.append(event)

    @property
    def threshold(self) -> int:
        return self._attrs["threshold"]

    def ready(self) -> bool:
        return len(self._events) >= self.threshold

    def wait(self, reset: bool = True,
             raise_on_error: bool = True) -> List[Event]:
        """Return the completed events.  In trace-time LCX, ops complete
        at ``progress()``; waiting before enough progress is a program
        error (there is no background thread to make it ready).

        A non-ok event (timeout, cancellation, fatal transport failure)
        raises :class:`CompletionError` — errors surface instead of
        counting as silent successes.  Pass ``raise_on_error=False`` to
        receive the events and inspect ``event.status`` yourself; on
        raise the events stay queued for inspection.
        """
        if not self.ready():
            raise RuntimeError(
                f"Synchronizer.wait(): only {len(self._events)} of "
                f"{self.threshold} completions arrived — call "
                "lcx.progress() after posting"
            )
        events, rest = (self._events[: self.threshold],
                        self._events[self.threshold:])
        if raise_on_error:
            bad = [e for e in events if not e.status.ok]
            if bad:
                raise CompletionError(
                    f"Synchronizer.wait(): {len(bad)} of {len(events)} "
                    f"completions failed: "
                    f"{sorted({e.status.value for e in bad})}", bad)
        if reset:
            self._events = rest
        return events

    def wait_payloads(self, reset: bool = True) -> List[Any]:
        return [e.payload for e in self.wait(reset=reset)]

    def error_events(self) -> List[Event]:
        """Arrived events carrying a non-ok status (without consuming)."""
        return [e for e in self._events if not e.status.ok]


class CompletionQueue(CompletionObject):
    """FIFO completion queue.

    A full queue does **not** raise from inside progress (which would
    lose the event and tear down the progress engine): ``signal``
    returns :attr:`ErrorCode.RETRY` and the progress engine converts it
    into a retry-status completion for the poster (or an automatic
    backoff re-post when the op carries ``max_retries``).
    """

    _ATTR_DEFAULTS = {"capacity": 1 << 16}

    def __init__(self, capacity: Optional[int] = None, **attrs: Any) -> None:
        super().__init__(capacity=capacity, **attrs)
        self._q: deque = deque()
        self.overflows = 0
        self.n_error_events = 0

    def signal(self, event: Event) -> ErrorCode:
        if len(self._q) >= self._attrs["capacity"]:
            self.overflows += 1
            return ErrorCode.RETRY
        if not event.status.ok:
            self.n_error_events += 1
        self._q.append(event)
        return ErrorCode.OK

    def pop(self) -> Optional[Event]:
        return self._q.popleft() if self._q else None

    def pop_all(self) -> List[Event]:
        out = list(self._q)
        self._q.clear()
        return out

    def __len__(self) -> int:
        return len(self._q)


class FunctionHandler(CompletionObject):
    """Completion object that invokes a function on each event — the
    active-message handler, usable as *local or remote* completion for any
    operation (paper: "LCI's active message operation supports remote
    completion objects of any type")."""

    def __init__(self, fn: Callable[[Event], Any], **attrs: Any) -> None:
        super().__init__(**attrs)
        self._fn = fn
        self.results: List[Any] = []

    def signal(self, event: Event) -> None:
        self.results.append(self._fn(event))


class CounterCompletion(CompletionObject):
    """Example of the paper's "overload ``signal`` with an atomic counter"
    pattern: becomes ready when N ops completed, keeps no payloads.

    Only ok-status completions advance the counter; failed completions
    are collected in :attr:`errors` so a lost transfer can never satisfy
    a success threshold silently."""

    _ATTR_DEFAULTS = {"target": 1}

    def __init__(self, target: Optional[int] = None, **attrs: Any) -> None:
        super().__init__(target=target, **attrs)
        self.count = 0
        self.errors: List[Event] = []

    def signal(self, event: Event) -> None:
        if event.status.ok:
            self.count += 1
        else:
            self.errors.append(event)

    def ready(self) -> bool:
        return self.count >= self._attrs["target"]

    @property
    def error_count(self) -> int:
        return len(self.errors)


# ---------------------------------------------------------------------------
# Matching engine
# ---------------------------------------------------------------------------
_NO_KEY = object()          # sentinel: match key not yet computed


@dataclasses.dataclass(eq=False)
class PostedOp:
    """A pending posted operation (trace-time analogue of an LCI
    communication descriptor)."""

    kind: str                    # "send" | "recv"
    buffer: Any                  # send: traced array; recv: ShapeDtype proto
    perm: Optional[Perm]
    tag: int
    comp: Optional[CompletionObject]
    device: "Device"
    seq: int
    context: Any = None
    remote_comp: Optional[CompletionObject] = None
    op_name: str = "send"        # original op: send/put/get/am
    allow_aggregation: bool = True
    # Match key, computed ONCE at post time by the matching engine the op
    # is posted to (it depends on the engine's policy).  _NO_KEY until then.
    match_key: Any = _NO_KEY
    # -- lifecycle (fault-tolerance) ----------------------------------------
    # "pending"   — posted, waiting in a matching engine
    # "matched"   — matched, waiting in the transfer ledger / retry queue
    # "done"      — completion signalled
    # "cancelled" / "timeout" / "fatal" — retired with that status
    state: str = "pending"
    engine: Optional["MatchingEngine"] = None
    timeout: Optional[int] = None      # deadline in progress calls
    max_retries: int = 0               # backoff re-posts on drop/overflow
    retries: int = 0                   # attempts consumed
    delays: int = 0                    # consecutive injected delays
    posted_tick: int = 0               # runtime tick at post time
    fault_mark: Optional[str] = None   # set by FaultyTransport for this hop
    migrated: bool = False             # re-homed by a device failover


class MatchingEngine(HasAttrs):
    """Matches posted sends with posted recvs.

    ``kind='map'`` matches on a key derived from the policy, regardless of
    posting order (the multithreaded-throughput implementation in the
    paper — LCI attributes its message-rate advantage to hash-table tag
    matching, and this engine mirrors that: keyed hash buckets give O(1)
    amortized post+match instead of the O(S×R) pending-list scan).
    ``kind='queue'`` only matches in FIFO order (in-order receives): a
    send matches the *head* recv and vice versa; a key mismatch at the
    heads leaves both pending (they may match after reordering posts —
    which, trace-time, means user error surfaced by ``flush``).

    Map-mode invariant: after every ``post`` no matchable (send, recv)
    pair remains pending, so a new op can only match the *oldest*
    pending opposite op with the same key — which is exactly the head of
    that key's bucket.  Custom ``key_fn``s returning unhashable keys
    fall back to a linear bucket scan with identical semantics.
    """

    _ATTR_DEFAULTS = {"kind": "map", "policy": "rank_tag"}
    POLICIES = ("none", "rank_only", "tag_only", "rank_tag", "custom")

    def __init__(self, kind: Optional[str] = None,
                 policy: Optional[str] = None,
                 key_fn: Optional[Callable[[PostedOp], Any]] = None,
                 **attrs: Any) -> None:
        self._init_attrs({"kind": kind, "policy": policy, **attrs})
        if self._attrs["kind"] not in ("map", "queue"):
            raise ValueError(f"unknown matching engine kind "
                             f"{self._attrs['kind']!r}")
        if self._attrs["policy"] not in self.POLICIES:
            raise ValueError(f"unknown match policy {self._attrs['policy']!r}")
        if self._attrs["policy"] == "custom" and key_fn is None:
            raise ValueError("custom match policy requires key_fn")
        self._key_fn = key_fn
        # queue kind: FIFO deques.  map kind: key -> deque buckets, plus
        # an unhashable-key overflow list ((key, op) pairs, linear scan).
        self._pending_send: deque = deque()
        self._pending_recv: deque = deque()
        self._send_buckets: Dict[Any, deque] = {}
        self._recv_buckets: Dict[Any, deque] = {}
        self._send_overflow: List[Tuple[Any, PostedOp]] = []
        self._recv_overflow: List[Tuple[Any, PostedOp]] = []
        self._n_send = 0
        self._n_recv = 0
        self.n_matched = 0

    # -- key derivation ------------------------------------------------------
    def _key(self, op: PostedOp) -> Any:
        """Derive (and cache on the op) the policy match key.  Computed
        once at post time; the cached value is reused on every later
        drain attempt instead of re-deriving perm keys in inner loops."""
        if op.match_key is not _NO_KEY:
            return op.match_key
        policy = self._attrs["policy"]
        if policy == "none":
            key = ()
        elif policy == "rank_only":
            key = op.perm.key(op.device.axis_size) if op.perm else ()
        elif policy == "tag_only":
            key = op.tag
        elif policy == "rank_tag":
            key = ((op.perm.key(op.device.axis_size) if op.perm else ()),
                   op.tag)
        else:
            key = self._key_fn(op)
        op.match_key = key
        return key

    # -- posting ---------------------------------------------------------------
    def post(self, op: PostedOp) -> List[Tuple[PostedOp, PostedOp]]:
        """Post an op; return newly formed (send, recv) matches."""
        op.engine = self
        if self._attrs["kind"] == "queue":
            if op.kind == "send":
                self._pending_send.append(op)
            else:
                self._pending_recv.append(op)
            matches = self._drain_queue()
        else:
            matches = self._post_map(op)
        for s, r in matches:
            s.state = r.state = "matched"
        return matches

    def _post_map(self, op: PostedOp) -> List[Tuple[PostedOp, PostedOp]]:
        key = self._key(op)
        is_send = op.kind == "send"
        other_buckets = self._recv_buckets if is_send else self._send_buckets
        other_overflow = self._recv_overflow if is_send else self._send_overflow
        try:
            bucket = other_buckets.get(key)
        except TypeError:                     # unhashable custom key
            return self._post_map_unhashable(op, key)
        peer: Optional[PostedOp] = None
        if bucket:
            peer = bucket.popleft()
            if not bucket:
                del other_buckets[key]
        elif other_overflow:
            # hashable key may still match an unhashable-keyed peer via ==
            for i, (okey, oop) in enumerate(other_overflow):
                if okey == key:
                    peer = oop
                    del other_overflow[i]
                    break
        if peer is None:
            own = self._send_buckets if is_send else self._recv_buckets
            own.setdefault(key, deque()).append(op)
            if is_send:
                self._n_send += 1
            else:
                self._n_recv += 1
            return []
        if is_send:
            self._n_recv -= 1
            match = (op, peer)
        else:
            self._n_send -= 1
            match = (peer, op)
        self.n_matched += 1
        return [match]

    def _post_map_unhashable(self, op: PostedOp,
                             key: Any) -> List[Tuple[PostedOp, PostedOp]]:
        is_send = op.kind == "send"
        other_buckets = self._recv_buckets if is_send else self._send_buckets
        other_overflow = self._recv_overflow if is_send else self._send_overflow
        peer: Optional[PostedOp] = None
        # oldest matching peer across bucketed and overflow pendings
        best_seq = None
        best_loc: Any = None
        for bkey, bucket in other_buckets.items():
            if bkey == key and bucket:
                head = bucket[0]
                if best_seq is None or head.seq < best_seq:
                    best_seq, best_loc, peer = head.seq, ("b", bkey), head
        for i, (okey, oop) in enumerate(other_overflow):
            if okey == key and (best_seq is None or oop.seq < best_seq):
                best_seq, best_loc, peer = oop.seq, ("o", i), oop
        if peer is None:
            own = self._send_overflow if is_send else self._recv_overflow
            own.append((key, op))
            if is_send:
                self._n_send += 1
            else:
                self._n_recv += 1
            return []
        if best_loc[0] == "b":
            bucket = other_buckets[best_loc[1]]
            bucket.popleft()
            if not bucket:
                del other_buckets[best_loc[1]]
        else:
            del other_overflow[best_loc[1]]
        if is_send:
            self._n_recv -= 1
            match = (op, peer)
        else:
            self._n_send -= 1
            match = (peer, op)
        self.n_matched += 1
        return [match]

    def _drain_queue(self) -> List[Tuple[PostedOp, PostedOp]]:
        matches: List[Tuple[PostedOp, PostedOp]] = []
        while self._pending_send and self._pending_recv:
            s, r = self._pending_send[0], self._pending_recv[0]
            if self._key(s) != self._key(r):
                break
            self._pending_send.popleft()
            self._pending_recv.popleft()
            matches.append((s, r))
        self.n_matched += len(matches)
        return matches

    # -- cancellation ----------------------------------------------------------
    def cancel(self, op: PostedOp) -> bool:
        """Retire a still-pending op from the engine's buckets.

        The op is removed *physically* (not tombstoned), so
        :meth:`pending` reflects the cancellation immediately rather
        than waiting for bucket compaction.  Returns ``False`` when the
        op already matched, completed, or belongs to another engine —
        too late to cancel."""
        if op.state != "pending" or op.engine is not self:
            return False
        if self._attrs["kind"] == "queue":
            q = self._pending_send if op.kind == "send" else self._pending_recv
            try:
                q.remove(op)
            except ValueError:
                return False
            return True
        # map kind: keyed bucket or unhashable overflow
        own_buckets = (self._send_buckets if op.kind == "send"
                       else self._recv_buckets)
        own_overflow = (self._send_overflow if op.kind == "send"
                        else self._recv_overflow)
        removed = False
        try:
            bucket = own_buckets.get(op.match_key)
        except TypeError:
            bucket = None
        if bucket is not None:
            try:
                bucket.remove(op)
                removed = True
                if not bucket:
                    del own_buckets[op.match_key]
            except ValueError:
                pass
        if not removed:
            for i, (_, oop) in enumerate(own_overflow):
                if oop is op:
                    del own_overflow[i]
                    removed = True
                    break
        if removed:
            if op.kind == "send":
                self._n_send -= 1
            else:
                self._n_recv -= 1
        return removed

    def pending(self) -> Tuple[int, int]:
        if self._attrs["kind"] == "queue":
            return len(self._pending_send), len(self._pending_recv)
        return self._n_send, self._n_recv

    # -- migration -------------------------------------------------------------
    def extract_pending(self, device: "Device") -> List[PostedOp]:
        """Remove and return every still-pending op posted on ``device``,
        in seq order (the order they were posted).  Used by
        :meth:`NetContext.migrate` to transplant a dead device's
        un-matched ops into the survivor's engine; the ops keep their
        cached ``match_key`` so tag/rank matching is preserved."""
        out: List[PostedOp] = []
        if self._attrs["kind"] == "queue":
            for q in (self._pending_send, self._pending_recv):
                keep = deque()
                for op in q:
                    (out if op.device is device else keep).append(op)
                q.clear()
                q.extend(keep)
        else:
            for buckets in (self._send_buckets, self._recv_buckets):
                for key in list(buckets):
                    bucket = buckets[key]
                    taken = [op for op in bucket if op.device is device]
                    if not taken:
                        continue
                    out.extend(taken)
                    kept = deque(op for op in bucket
                                 if op.device is not device)
                    if kept:
                        buckets[key] = kept
                    else:
                        del buckets[key]
            for overflow in (self._send_overflow, self._recv_overflow):
                taken = [op for _, op in overflow if op.device is device]
                if taken:
                    out.extend(taken)
                    overflow[:] = [(k, op) for k, op in overflow
                                   if op.device is not device]
            for op in out:
                if op.kind == "send":
                    self._n_send -= 1
                else:
                    self._n_recv -= 1
        for op in out:
            op.engine = None
        out.sort(key=lambda op: op.seq)
        return out


# ---------------------------------------------------------------------------
# Packet pool
# ---------------------------------------------------------------------------
class PacketPool(HasAttrs):
    """Pre-registered fixed-size buffer pool.

    Messages with ``nbytes <= packet_size`` travel the *eager* path and
    are eligible for aggregation: at progress time all eager messages
    sharing a (axis, perm) pattern are packed into one transfer.  Larger
    messages take the *rendezvous* path (their own transfer) — mirroring
    LCI's eager/rendezvous split.
    """

    _ATTR_DEFAULTS = {"npackets": 4096, "packet_size": 65536,
                      "aggregate": True}

    def __init__(self, npackets: Optional[int] = None,
                 packet_size: Optional[int] = None, **attrs: Any) -> None:
        self._init_attrs(
            {"npackets": npackets, "packet_size": packet_size, **attrs})
        self.stats = {"eager_msgs": 0, "rendezvous_msgs": 0,
                      "aggregated_transfers": 0, "raw_transfers": 0}

    def is_eager(self, nbytes: int) -> bool:
        return nbytes <= self._attrs["packet_size"]


# ---------------------------------------------------------------------------
# NetContext
# ---------------------------------------------------------------------------
class NetContext(HasAttrs):
    """The per-backend network context (second hierarchy level).

    One net context per network backend: ``"xla"`` (compiled
    collectives), ``"pallas"`` (remote-DMA kernels, TPU-only), ``"sim"``
    (loopback).  A net context owns :class:`Device` objects; devices
    created through :meth:`device` inherit the context's backend and own
    private matching/pool/completion resources by default — the
    library-interop pattern (one device per library) and the
    per-thread-device isolation both hang off this level.
    """

    _ATTR_DEFAULTS = {
        "backend": "xla",        # "xla" | "pallas" (TPU-only) | "sim"
        "name": None,
    }

    def __init__(self, runtime: Optional["Runtime"] = None,
                 backend: Optional[str] = None, **attrs: Any) -> None:
        self._init_attrs({"backend": backend, **attrs})
        if self._attrs["backend"] not in ("xla", "pallas", "sim"):
            raise ValueError(
                f"unknown net-context backend {self._attrs['backend']!r}")
        self._runtime = runtime
        self.devices: List["Device"] = []
        self.default_device: Optional["Device"] = None
        if runtime is not None:
            runtime._attach_net_context(self)

    @property
    def runtime(self) -> Optional["Runtime"]:
        return self._runtime

    @property
    def backend(self) -> str:
        return self._attrs["backend"]

    def device(self, axis: Optional[str] = None, **attrs: Any) -> "Device":
        """Allocate a device on this context.  Unlike bare ``Device()``,
        the device owns private resources (``own_resources=True``)
        unless explicitly disabled."""
        attrs.setdefault("own_resources", True)
        attrs.setdefault("backend", self.backend)
        return Device(axis=axis, net_context=self, **attrs)

    def _attach_device(self, dev: "Device") -> None:
        self.devices.append(dev)
        if self.default_device is None:
            self.default_device = dev

    def pending(self) -> int:
        """Matched-but-unprogressed transfers across this context's
        devices (0 when unbound to a runtime)."""
        rt = self._runtime
        if rt is None:
            return 0
        return sum(rt.pending_for(d) for d in self.devices)

    # -- failover --------------------------------------------------------------
    def migrate(self, dead: "Device", target: "Device",
                replay: bool = True) -> "MigrationReport":
        """Re-home a dead (or dying) device's communication state onto
        ``target``: endpoints move over, un-matched posted ops
        transplant into the target's matching engine (tag/rank match
        keys preserved), and matched-but-unprogressed transfers in the
        runtime's ledger/retry queue re-point to the survivor.

        Replay semantics: when ``replay`` is true and the two devices
        communicate over the *same axis*, in-flight transfers replay
        transparently on the survivor — deliveries carry
        ``Event.migrated=True`` and the runtime's per-op sequence
        numbers + dedup window guarantee a transfer that raced the
        failure is neither lost nor double-delivered.  When the axes
        differ (or ``replay=False``), matched pairs cannot replay: both
        sides complete ``retry`` with ``migrated=True`` so the poster
        (e.g. the AMT executor) re-posts on the survivor.

        The dead device is marked dead and left with a ``migrated_to``
        forwarding pointer, so stale handles posting through it resolve
        to the target."""
        if dead is target:
            raise ValueError("cannot migrate a device onto itself")
        if not target.alive:
            raise ValueError(f"migration target {target!r} is dead")
        rt = self._runtime
        if rt is None:
            rt = target.runtime or dead.runtime
        if rt is None:
            rt = _global_runtime()
        can_replay = replay and dead.axis == target.axis
        target_engine = target.engine
        if target_engine is None:      # floating target: ambient default
            target_engine = rt.default_engine
        # 1. un-matched engine-pending ops: pull them (seq order) out of
        #    whatever engine they pend in and transplant.
        moved_ops: List[PostedOp] = []
        engines = []
        if dead.engine is not None:
            engines.append(dead.engine)
        for ep in dead.endpoints:
            if ep.engine is not None and ep.engine not in engines:
                engines.append(ep.engine)
        if rt.default_engine is not None and rt.default_engine not in engines:
            engines.append(rt.default_engine)
        for eng in engines:
            moved_ops.extend(eng.extract_pending(dead))
        moved_ops.sort(key=lambda op: op.seq)
        n_signalled = 0
        for op in moved_ops:
            op.device = target
            op.migrated = True
            if not can_replay:
                # match keys derived from (perm, axis_size) no longer
                # describe the survivor's axis: recompute at re-post.
                op.match_key = _NO_KEY
            rt.enqueue_matches(target_engine.post(op))
        # 2. matched transfers in the ledger / retry queue.
        n_ledger, n_retry, sig = rt.retarget_pending(
            dead, target, can_replay=can_replay)
        n_signalled += sig
        # 3. endpoints re-home (their resource aliases follow the target
        #    when they aliased the dead device's own resources).
        n_eps = 0
        for ep in list(dead.endpoints):
            if ep in target.endpoints:
                continue
            if ep.engine is dead.engine:
                ep.engine = target.engine
            if ep.pool is dead.pool:
                ep.pool = target.pool
            if ep.cq is dead.cq:
                ep.cq = target.cq
            ep.device = target
            target.endpoints.append(ep)
            n_eps += 1
        dead.endpoints = []
        dead.mark_dead()
        dead.migrated_to = target
        return MigrationReport(dead=dead, target=target, replayed=can_replay,
                               n_endpoints=n_eps, n_engine_ops=len(moved_ops),
                               n_ledger=n_ledger, n_retry=n_retry,
                               n_reposted=n_signalled)

    def __repr__(self) -> str:
        name = self._attrs.get("name")
        tag = f" {name!r}" if name else ""
        return (f"NetContext<{self.backend}{tag}, "
                f"{len(self.devices)} device(s)>")


@dataclasses.dataclass
class MigrationReport:
    """What :meth:`NetContext.migrate` moved.  ``replayed`` is True when
    in-flight transfers replay transparently on the survivor;
    ``n_reposted`` counts matched pairs that instead completed
    ``retry``/``migrated`` for the poster to re-post."""

    dead: "Device"
    target: "Device"
    replayed: bool
    n_endpoints: int = 0
    n_engine_ops: int = 0
    n_ledger: int = 0
    n_retry: int = 0
    n_reposted: int = 0


# ---------------------------------------------------------------------------
# Device
# ---------------------------------------------------------------------------
class Device(HasAttrs):
    """The per-communicator network resource (third hierarchy level).

    ``axis`` names the mesh axis this device communicates over (its
    "NIC port" onto the ICI torus); ``axis=None`` is the loopback/sim
    device used for single-process semantics tests.  Multiple devices on
    the same axis model LCI's device-per-thread isolation: their pending
    traffic is progressed independently (separate transfer schedules).

    Devices allocated through the hierarchy (``net_ctx.device(...)`` /
    ``rt.device(...)``) own a *private* matching engine, packet pool,
    and completion queue plus a default :class:`Endpoint` — ops posted
    on them cannot contend with (or match against) another device's
    traffic.  A bare ``Device(axis=...)`` stays *floating*: it carries
    no private resources and resolves them from the ambient runtime's
    defaults (the legacy shared-engine behaviour — sends and recvs
    posted on different floating devices still match when they share
    the default engine).
    """

    _ATTR_DEFAULTS = {
        "axis": None,            # mesh axis name (str) or None = loopback
        "backend": "xla",        # "xla" | "pallas" (TPU-only) | "sim"
        "max_inflight": 64,       # max transfers materialized per progress
        "allow_payload_metadata": True,
        "mesh_shape": None,       # optional dict axis->size when not in ctx
        "own_resources": False,   # private engine/pool/cq (+ endpoint)
        "name": None,
    }

    def __init__(self, axis: Optional[str] = None,
                 net_context: Optional[NetContext] = None,
                 **attrs: Any) -> None:
        self._init_attrs({"axis": axis, **attrs})
        self.stats = {"posted": 0, "transfers": 0, "progressed": 0,
                      "bytes_moved": 0}
        self.alive = True
        # ``responsive`` models the *health signal*: a frozen device
        # (silent death — still "alive" as far as anyone has declared,
        # but no longer answering progress pings) stops beating and its
        # pending transfers stall until a HeartbeatMonitor declares it
        # dead and triggers failover.
        self.responsive = True
        self.last_beat = 0           # runtime tick of the last heartbeat
        # Forwarding pointer set by NetContext.migrate: stale handles to
        # a migrated device resolve (via resolve_resources) to the
        # survivor, chained if the survivor itself later migrates.
        self.migrated_to: Optional["Device"] = None
        self._net_context = net_context
        self.endpoints: List["Endpoint"] = []
        self.transport: Optional["FaultyTransport"] = None
        self.engine: Optional[MatchingEngine] = None
        self.pool: Optional[PacketPool] = None
        self.cq: Optional[CompletionQueue] = None
        self.default_endpoint: Optional["Endpoint"] = None
        if self._attrs["own_resources"]:
            self.engine = MatchingEngine()
            self.pool = PacketPool()
            self.cq = CompletionQueue()
            self.default_endpoint = self.endpoint()
        if net_context is not None:
            net_context._attach_device(self)

    @property
    def net_context(self) -> Optional[NetContext]:
        return self._net_context

    @property
    def runtime(self) -> Optional["Runtime"]:
        """The runtime this device hangs off (None when floating)."""
        return self._net_context.runtime if self._net_context else None

    def endpoint(self, matching_engine: Optional[MatchingEngine] = None,
                 pool: Optional[PacketPool] = None,
                 cq: Optional[CompletionQueue] = None,
                 **attrs: Any) -> "Endpoint":
        """Allocate a posting endpoint on this device, optionally with a
        private matching engine / packet pool / completion queue."""
        return Endpoint(self, matching_engine=matching_engine, pool=pool,
                        cq=cq, **attrs)

    def install_transport(
            self, transport: Optional["FaultyTransport"]
    ) -> Optional["FaultyTransport"]:
        """Install (or, with ``None``, remove) a fault-injecting
        transport on *this device only*: matched transfers whose send
        side sits on this device route through it at progress time.
        Returns the previous transport.  The module-level
        :func:`install_transport` delegates here for every device of the
        default runtime (plus the runtime-wide fallback for floating
        devices)."""
        prev, self.transport = self.transport, transport
        return prev

    def pending(self, runtime: Optional["Runtime"] = None) -> int:
        """Matched-but-unprogressed transfers ledgered on this device in
        ``runtime`` (defaults to the device's own runtime, else the
        global one)."""
        rt = runtime if runtime is not None else self.runtime
        if rt is None:
            rt = _global_runtime()
        return rt.pending_for(self)

    def mark_dead(self) -> None:
        """Declare this device failed.  Matched transfers touching a
        dead device drain as ``fatal`` completions at the next progress
        call (or immediately via ``runtime().drain_dead``) instead of
        hanging their completion objects forever."""
        self.alive = False
        self.responsive = False

    def freeze(self) -> None:
        """Silent death: the device stops answering progress pings (no
        more heartbeats, its matched transfers stall in the ledger) but
        nobody has *declared* it dead yet.  A
        :class:`repro.runtime.fault.HeartbeatMonitor` attached to the
        runtime notices the missing beats and triggers the configured
        ``on_dead`` policy (failover / drain / raise)."""
        self.responsive = False

    def unfreeze(self) -> None:
        if self.alive:
            self.responsive = True

    def resolve_migrated(self) -> "Device":
        """Follow the ``migrated_to`` forwarding chain to the device
        currently serving this handle's traffic (self when never
        migrated)."""
        dev: "Device" = self
        seen = set()
        while dev.migrated_to is not None and id(dev) not in seen:
            seen.add(id(dev))
            dev = dev.migrated_to
        return dev

    def __repr__(self) -> str:
        name = self._attrs.get("name")
        tag = f"{name!r}, " if name else ""
        own = ", own" if self._attrs["own_resources"] else ""
        return f"Device<{tag}axis={self.axis!r}{own}>@{id(self):x}"

    @property
    def axis(self) -> Optional[str]:
        return self._attrs["axis"]

    @property
    def axis_size(self) -> int:
        axis = self.axis
        if axis is None:
            return 1
        ms = self._attrs.get("mesh_shape")
        if ms and axis in ms:
            return int(ms[axis])
        # Inside shard_map the axis is bound; query its size.
        from repro.compat import axis_size
        try:
            return axis_size(axis)
        except NameError:
            raise RuntimeError(
                f"Device axis {axis!r} is not bound — post LCX ops under "
                "shard_map over that axis, or pass mesh_shape attr"
            )


# ---------------------------------------------------------------------------
# Endpoint
# ---------------------------------------------------------------------------
class Endpoint(HasAttrs):
    """The posting resource on a device (fourth hierarchy level).

    LCI allocates one endpoint per thread (or per library) on a device;
    here an endpoint is the handle ops are posted through:
    ``send_x(buf).endpoint(ep)()`` resolves every unset resource from
    the endpoint first — its matching engine, packet pool, and default
    completion queue — before falling back to the device, net-context,
    and runtime defaults (:func:`resolve_resources`).

    By default an endpoint aliases its device's private resources; pass
    ``matching_engine=`` / ``pool=`` / ``cq=`` for a fully isolated
    endpoint (two endpoints with separate engines on one device never
    match each other's traffic).
    """

    _ATTR_DEFAULTS = {"name": None}

    def __init__(self, device: Device,
                 matching_engine: Optional[MatchingEngine] = None,
                 pool: Optional[PacketPool] = None,
                 cq: Optional[CompletionQueue] = None,
                 **attrs: Any) -> None:
        self._init_attrs(attrs)
        self.device = device
        self.engine = matching_engine if matching_engine is not None \
            else device.engine
        self.pool = pool if pool is not None else device.pool
        self.cq = cq if cq is not None else device.cq
        self.stats = {"posted": 0}
        device.endpoints.append(self)

    @property
    def runtime(self) -> Optional["Runtime"]:
        return self.device.runtime

    def __repr__(self) -> str:
        name = self._attrs.get("name")
        tag = f"{name!r} " if name else ""
        return f"Endpoint<{tag}on {self.device!r}>"


# ---------------------------------------------------------------------------
# Memory registration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class MemoryRegion:
    """Explicit memory registration (paper §2.2: reuse registrations to
    reduce overhead).  In XLA the analogue of registration cost is layout/
    donation setup; we track reuse so benchmarks can report it."""

    array: Any
    registration_id: int
    uses: int = 0


# ---------------------------------------------------------------------------
# Fault-injecting transport (seeded, deterministic, CPU-testable)
# ---------------------------------------------------------------------------
def signal_error(s: PostedOp, r: PostedOp, code: ErrorCode,
                 migrated: bool = False) -> None:
    """Deliver a non-ok completion to both sides of a matched pair
    (payload-less: the transfer never happened).  ``migrated=True``
    stamps the events as failover fallout — consumers treat a
    ``retry``-status migrated completion as "re-post on the survivor",
    not as a loss."""
    s.state = r.state = code.value
    if s.comp is not None:
        s.comp.signal(Event(payload=None, op=s.op_name, tag=s.tag,
                            perm=s.perm, remote=False, context=s.context,
                            status=code, migrated=migrated))
    if r.comp is not None:
        remote = s.op_name in ("put", "am")
        r.comp.signal(Event(payload=None, op=s.op_name, tag=r.tag,
                            perm=r.perm, remote=remote, context=r.context,
                            status=code, migrated=migrated))


@dataclasses.dataclass
class FaultPolicy:
    """Seeded fault schedule for :class:`FaultyTransport`.

    Rates are per matched transfer per progress attempt; they must sum
    to at most 1.  ``corrupt_mark=True`` stamps corrupted deliveries
    with :attr:`ErrorCode.RETRY` (an integrity-checked link); ``False``
    corrupts silently (the checksum-free link — higher layers must
    detect).  ``max_delays`` bounds consecutive delays per transfer so a
    pathological ``delay=1.0`` policy still terminates."""

    seed: int = 0
    drop: float = 0.0
    delay: float = 0.0
    duplicate: float = 0.0
    corrupt: float = 0.0
    corrupt_mark: bool = True
    max_delays: int = 16

    def __post_init__(self) -> None:
        total = self.drop + self.delay + self.duplicate + self.corrupt
        if not 0.0 <= total <= 1.0:
            raise ValueError(f"fault rates must sum to [0, 1], got {total}")


class FaultyTransport:
    """Injectable transport faults, mirroring the
    :class:`repro.runtime.fault.FailureInjector` idiom: every decision
    comes from one seeded RNG, so a given (policy, workload) pair
    replays identically on CPU.

    Applied by ``progress()`` to each matched transfer before execution:

    - **drop** — the transfer is lost.  With retries remaining
      (``max_retries`` on the post) it is re-posted after exponential
      backoff; otherwise both sides complete with ``fatal``.
    - **delay** — the match is re-enqueued; it needs extra progress
      calls to land (bounded by ``policy.max_delays``).
    - **duplicate** — the receiver's completion object is signalled
      twice with the same payload.
    - **corrupt** — the payload arrives bitwise-inverted, stamped
      ``retry`` when ``policy.corrupt_mark``.
    """

    def __init__(self, policy: Optional[FaultPolicy] = None,
                 **policy_kwargs: Any) -> None:
        self.policy = policy if policy is not None \
            else FaultPolicy(**policy_kwargs)
        self._rng = random.Random(self.policy.seed)
        self.stats = {"transfers": 0, "drops": 0, "delays": 0,
                      "duplicates": 0, "corruptions": 0, "retries": 0,
                      "fatal": 0}

    def decide(self) -> str:
        u = self._rng.random()
        p = self.policy
        if u < p.drop:
            return "drop"
        u -= p.drop
        if u < p.delay:
            return "delay"
        u -= p.delay
        if u < p.duplicate:
            return "duplicate"
        u -= p.duplicate
        if u < p.corrupt:
            return "corrupt"
        return "ok"

    def apply(self, matches: List[Tuple[PostedOp, PostedOp]],
              rt: Optional["Runtime"] = None
              ) -> List[Tuple[PostedOp, PostedOp]]:
        """Fault-filter matched pairs; returns the ones to execute now.
        Dropped pairs go to the retry queue (or fail fatally); delayed
        pairs go back to the ledger; duplicate/corrupt pairs pass
        through with a ``fault_mark`` the execution path consumes.
        ``rt`` is the runtime whose ledger/retry queue absorbs delayed
        and dropped pairs (defaults to the global one)."""
        if rt is None:
            rt = runtime()
        out: List[Tuple[PostedOp, PostedOp]] = []
        for s, r in matches:
            self.stats["transfers"] += 1
            action = self.decide()
            if action == "delay" and s.delays >= self.policy.max_delays:
                action = "ok"
            if action == "drop":
                self.stats["drops"] += 1
                if rt.schedule_retry(s, r):
                    self.stats["retries"] += 1
                else:
                    self.stats["fatal"] += 1
                    signal_error(s, r, ErrorCode.FATAL)
            elif action == "delay":
                self.stats["delays"] += 1
                s.delays += 1
                rt.enqueue_matches([(s, r)])
            elif action == "duplicate":
                self.stats["duplicates"] += 1
                s.fault_mark = "duplicate"
                out.append((s, r))
            elif action == "corrupt":
                self.stats["corruptions"] += 1
                s.fault_mark = ("corrupt" if self.policy.corrupt_mark
                                else "corrupt_silent")
                out.append((s, r))
            else:
                s.delays = 0
                out.append((s, r))
        return out


# ---------------------------------------------------------------------------
# Runtime (default resources + pending transfer ledger)
# ---------------------------------------------------------------------------
_RUNTIME_IDS = itertools.count(1)


class Runtime:
    """Top of the resource hierarchy: default resources, the
    pending-transfer ledger, and the fault clocks.

    The paper: "There will be a default set of resources allocated by the
    runtime.  Users only need to explicitly manage resources when they
    find it necessary.  Users can also disable this default resource
    allocation."

    A Runtime is independently constructible — ``Runtime()`` gives a
    fully isolated instance whose traffic, ``pending()`` accounting,
    fault injection, and :meth:`finalize` leak check never touch the
    global default runtime (which is itself just a lazily created
    ``Runtime`` — the ``g_runtime`` idiom).  Default resources are
    allocated *through the hierarchy*: one :class:`NetContext`, holding
    one default :class:`Device` with a private engine/pool/completion
    queue and a default :class:`Endpoint`; ``default_engine`` etc. are
    views onto that default device's resources.
    """

    def __init__(self, alloc_default_resources: bool = True,
                 default_axis: Optional[str] = None,
                 name: Optional[str] = None,
                 dedup_window: int = 4096) -> None:
        self.name = name or f"runtime-{next(_RUNTIME_IDS)}"
        self._seq = itertools.count()
        self._reg_ids = itertools.count(1)
        self.net_contexts: List[NetContext] = []
        self.default_net_context: Optional[NetContext] = None
        self.default_device: Optional[Device] = None
        self.default_endpoint: Optional[Endpoint] = None
        self.default_pool: Optional[PacketPool] = None
        self.default_engine: Optional[MatchingEngine] = None
        self.default_cq: Optional[CompletionQueue] = None
        if alloc_default_resources:
            nc = self.net_context()
            dev = nc.device(axis=default_axis)
            self.default_device = dev
            self.default_endpoint = dev.default_endpoint
            self.default_pool = dev.pool
            self.default_engine = dev.engine
            self.default_cq = dev.cq
        # (send, recv) matches waiting for a progress() call, ledgered
        # per device so take_ready(device) is an O(1) dict pop instead of
        # a quadratic filter over one global list.  A cross-device match
        # (shared engine, different devices) is indexed under BOTH
        # devices; entries are [match, taken] cells so whichever ledger
        # is drained first claims the match.  Keys are the Device objects
        # themselves (identity-hashed) so leak reports can name them.
        self._ready: Dict[Device, List[List[Any]]] = {}
        self._n_pending = 0
        # Fault path: progress-call tick counter, optional fault-injecting
        # transport, backoff retry queue (min-heap on release tick), and
        # the deadline watchlist for ops posted with a timeout.
        self.tick = 0
        self.transport: Optional[FaultyTransport] = None
        self._retry_q: List[Tuple[int, int, Tuple[PostedOp, PostedOp]]] = []
        self._timed: List[PostedOp] = []
        # Failover machinery: an optional heartbeat monitor polled each
        # progress tick (duck-typed: anything with ``poll(rt)``), and the
        # delivered-seq dedup window that makes post-migration replay
        # exactly-once (a migrated transfer whose seq already delivered
        # is suppressed; the window is bounded so memory stays flat).
        self.heartbeat: Optional[Any] = None
        self._dedup_window = max(1, int(dedup_window))
        self._delivered_seqs: set = set()
        self._delivered_order: deque = deque()
        self.failover_stats = {"failovers": 0, "migrated_ops": 0,
                               "dedup_suppressed": 0, "replayed": 0,
                               "reposted": 0}
        # Aggregation-plan cache: (axis, perm-key, dtype-sig, shape-sig)
        # -> concat/slice layout, reused across progress calls so
        # steady-state loops don't re-derive pack/unpack plans.
        self.agg_plans: Dict[Any, Any] = {}
        self.plan_stats: Dict[str, int] = {"hits": 0, "misses": 0}
        self._rcomp_registry: Dict[int, CompletionObject] = {}
        self._rcomp_next = itertools.count(1)
        self._lock = threading.Lock()

    # -- hierarchy ----------------------------------------------------------
    def _attach_net_context(self, nc: "NetContext") -> None:
        self.net_contexts.append(nc)
        if self.default_net_context is None:
            self.default_net_context = nc

    def net_context(self, backend: Optional[str] = None,
                    **attrs: Any) -> "NetContext":
        """Allocate a new :class:`NetContext` owned by this runtime."""
        return NetContext(runtime=self, backend=backend, **attrs)

    def device(self, axis: Optional[str] = None, **attrs: Any) -> "Device":
        """Allocate an isolated device (private engine/pool/cq) on this
        runtime's default net context, creating one if needed."""
        nc = self.default_net_context
        if nc is None:
            nc = self.net_context()
        return nc.device(axis=axis, **attrs)

    def devices(self) -> List["Device"]:
        """Every device attached to this runtime, across net contexts."""
        return [d for nc in self.net_contexts for d in nc.devices]

    # -- sequencing ---------------------------------------------------------
    def next_seq(self) -> int:
        return next(self._seq)

    # -- remote completion registry ------------------------------------------
    def register_rcomp(self, comp: CompletionObject) -> int:
        rid = next(self._rcomp_next)
        if rid >= (1 << MAX_RCOMP_BITS):
            raise RuntimeError("remote completion handler space exhausted")
        self._rcomp_registry[rid] = comp
        return rid

    def rcomp(self, rid: int) -> CompletionObject:
        return self._rcomp_registry[rid]

    # -- memory registration ---------------------------------------------------
    def register_memory(self, array: Any) -> MemoryRegion:
        return MemoryRegion(array=array, registration_id=next(self._reg_ids))

    # -- match ledger -----------------------------------------------------------
    def enqueue_matches(
            self, matches: List[Tuple[PostedOp, PostedOp]]) -> None:
        for m in matches:
            entry = [m, False]
            d0 = m[0].device
            self._ready.setdefault(d0, []).append(entry)
            d1 = m[1].device
            if d1 is not d0:
                self._ready.setdefault(d1, []).append(entry)
            self._n_pending += 1

    def take_ready(self, device: Optional[Device] = None
                   ) -> List[Tuple[PostedOp, PostedOp]]:
        out: List[Tuple[PostedOp, PostedOp]] = []
        if device is None:
            for ledger in self._ready.values():
                for entry in ledger:
                    if not entry[1]:
                        entry[1] = True
                        out.append(entry[0])
            self._ready.clear()
        else:
            for entry in self._ready.pop(device, ()):
                if not entry[1]:
                    entry[1] = True
                    out.append(entry[0])
        self._n_pending -= len(out)
        return out

    def pending_count(self) -> int:
        # backoff-queued retries are still in flight: they re-enter the
        # ledger when due, so they count toward backpressure and the
        # finalize() leak check
        return self._n_pending + len(self._retry_q)

    def pending_for(self, device: Device) -> int:
        """Matched-but-unprogressed transfers touching ``device``
        (ledger entries plus backoff-queued retries)."""
        n = sum(1 for entry in self._ready.get(device, ()) if not entry[1])
        n += sum(1 for _, _, (s, r) in self._retry_q
                 if s.device is device or r.device is device)
        return n

    def pending_by_device(self) -> Dict[Device, int]:
        """Per-device pending breakdown.  A cross-device match counts
        under both of its devices, so the sum may exceed
        :meth:`pending_count`."""
        out: Dict[Device, int] = {}
        for dev, ledger in self._ready.items():
            n = sum(1 for entry in ledger if not entry[1])
            if n:
                out[dev] = n
        for _, _, (s, r) in self._retry_q:
            for dev in {id(s.device): s.device, id(r.device): r.device}.values():
                out[dev] = out.get(dev, 0) + 1
        return out

    def finalize(self, strict: bool = True) -> None:
        """Leak-check this runtime.  With ``strict`` raises if any
        matched transfer was never progressed, naming the devices the
        leaks sit on."""
        n = self.pending_count()
        if strict and n:
            per_dev = ", ".join(
                f"{dev!r}: {cnt}"
                for dev, cnt in self.pending_by_device().items())
            raise RuntimeError(
                f"lcx.finalize(): {n} matched transfers never progressed "
                f"on {self.name} ({per_dev})")
        self._ready.clear()
        self._retry_q = []
        self._n_pending = 0

    # -- fault path: retries, deadlines, dead devices -------------------------
    def schedule_retry(self, s: PostedOp, r: PostedOp) -> bool:
        """Queue a lost/backpressured matched pair for an exponential-
        backoff re-post.  Returns False (caller must surface an error)
        when the pair has no retry budget left or its deadline already
        elapsed."""
        budget = max(s.max_retries, r.max_retries)
        if s.retries >= budget:
            return False
        if s.timeout is not None and \
                self.tick - s.posted_tick >= s.timeout:
            return False
        s.retries += 1
        backoff = 1 << (s.retries - 1)
        heapq.heappush(self._retry_q,
                       (self.tick + backoff, s.seq, (s, r)))
        return True

    def release_retries(self) -> None:
        """Move due retry entries back into the transfer ledger; expire
        the ones whose op deadline passed while backing off."""
        while self._retry_q and self._retry_q[0][0] <= self.tick:
            _, _, (s, r) = heapq.heappop(self._retry_q)
            if s.timeout is not None and \
                    self.tick - s.posted_tick >= s.timeout:
                signal_error(s, r, ErrorCode.TIMEOUT)
                continue
            self.enqueue_matches([(s, r)])

    def watch_deadline(self, op: PostedOp) -> None:
        op.posted_tick = self.tick
        if op.timeout is not None:
            self._timed.append(op)

    def expire_timeouts(self) -> None:
        """Retire engine-pending ops whose progress-call deadline passed:
        they are cancelled out of the matching engine and their
        completion object receives a ``timeout`` event."""
        if not self._timed:
            return
        still: List[PostedOp] = []
        for op in self._timed:
            if op.state != "pending":
                continue                      # matched/retired: deadline moot
            if self.tick - op.posted_tick < op.timeout:
                still.append(op)
                continue
            if op.engine is not None:
                op.engine.cancel(op)
            op.state = "timeout"
            if op.comp is not None:
                op.comp.signal(Event(payload=None, op=op.op_name, tag=op.tag,
                                     perm=op.perm, remote=False,
                                     context=op.context,
                                     status=ErrorCode.TIMEOUT))
        self._timed = still

    def drain_dead(self, device: Optional[Device] = None) -> int:
        """Drain matched transfers touching a dead device as ``fatal``
        completions.  With ``device=None`` every ledger entry whose send
        or recv device died is drained.  Returns the drain count."""
        drained = 0
        for s, r in self.take_ready(device):
            if s.device.alive and r.device.alive:
                self.enqueue_matches([(s, r)])   # healthy: put it back
            else:
                signal_error(s, r, ErrorCode.FATAL)
                drained += 1
        keep: List[Tuple[int, int, Tuple[PostedOp, PostedOp]]] = []
        for entry in self._retry_q:
            s, r = entry[2]
            if s.device.alive and r.device.alive:
                keep.append(entry)
            else:
                signal_error(s, r, ErrorCode.FATAL)
                drained += 1
        if len(keep) != len(self._retry_q):
            heapq.heapify(keep)
            self._retry_q = keep
        return drained

    def has_inflight(self) -> bool:
        """True while time-based work (backoff retries, armed deadlines)
        can still make progress — callers polling the engine should keep
        driving ``progress()`` rather than declare deadlock.  With a
        heartbeat monitor attached, ledger entries stalled on a frozen
        device also count: the monitor will declare the device dead and
        fail the transfers over (or drain them), so they are recoverable
        by driving more progress."""
        if self._retry_q:
            return True
        if self.heartbeat is not None and self._n_pending:
            return True
        return any(op.state == "pending" for op in self._timed)

    # -- failover: dedup window, ledger retarget, survivor choice -------------
    def note_delivered(self, seq: int) -> None:
        """Record an op seq whose receiver-side delivery was absorbed.
        The window is bounded (``dedup_window``): old seqs age out, so a
        migrated replay arriving *after* eviction delivers again — the
        window must cover the failure-detection latency, not history."""
        if seq in self._delivered_seqs:
            return
        self._delivered_seqs.add(seq)
        self._delivered_order.append(seq)
        while len(self._delivered_order) > self._dedup_window:
            self._delivered_seqs.discard(self._delivered_order.popleft())

    def was_delivered(self, seq: int) -> bool:
        return seq in self._delivered_seqs

    def retarget_pending(self, dead: Device, target: Device,
                         can_replay: bool = True) -> Tuple[int, int, int]:
        """Re-point ledger/retry-queue matches touching ``dead`` at
        ``target``.  Replayable pairs re-enqueue (marked migrated);
        non-replayable ones complete ``retry``+``migrated`` on both
        sides.  Returns (n_ledger, n_retry, n_signalled)."""
        def _repoint(s: PostedOp, r: PostedOp) -> None:
            if s.device is dead:
                s.device = target
            if r.device is dead:
                r.device = target
            s.migrated = r.migrated = True

        n_ledger = n_retry = n_signalled = 0
        for s, r in self.take_ready(dead):
            if s.device is not dead and r.device is not dead:
                self.enqueue_matches([(s, r)])   # foreign entry: put back
                continue
            n_ledger += 1
            _repoint(s, r)
            if can_replay:
                self.enqueue_matches([(s, r)])
            else:
                signal_error(s, r, ErrorCode.RETRY, migrated=True)
                n_signalled += 1
        keep: List[Tuple[int, int, Tuple[PostedOp, PostedOp]]] = []
        for entry in self._retry_q:
            s, r = entry[2]
            if s.device is not dead and r.device is not dead:
                keep.append(entry)
                continue
            n_retry += 1
            _repoint(s, r)
            if can_replay:
                keep.append(entry)
            else:
                signal_error(s, r, ErrorCode.RETRY, migrated=True)
                n_signalled += 1
        if len(keep) != len(self._retry_q):
            heapq.heapify(keep)
            self._retry_q = keep
        return n_ledger, n_retry, n_signalled

    def failover(self, dev: Device, target: Optional[Device] = None,
                 replay: bool = True) -> "MigrationReport":
        """Migrate ``dev``'s communication state onto a survivor.

        Without an explicit ``target``, picks the least-loaded alive
        device (fewest pending transfers), preferring same-net-context,
        same-axis candidates — endpoints, un-matched ops, and in-flight
        ledger entries move per :meth:`NetContext.migrate`.  Raises
        ``RuntimeError`` when no survivor exists."""
        if target is None:
            def rank(d: Device) -> Tuple[int, int, int]:
                same_nc = 0 if d.net_context is dev.net_context else 1
                same_axis = 0 if d.axis == dev.axis else 1
                return (same_nc, same_axis, self.pending_for(d))

            candidates = [d for d in self.devices()
                          if d is not dev and d.alive and d.responsive]
            if not candidates:
                raise RuntimeError(
                    f"failover({dev!r}): no alive device left on "
                    f"{self.name}")
            target = min(candidates, key=rank)
        nc = dev.net_context or target.net_context \
            or self.default_net_context
        if nc is None:
            nc = self.net_context()
        report = nc.migrate(dev, target, replay=replay)
        self.failover_stats["failovers"] += 1
        self.failover_stats["migrated_ops"] += (
            report.n_engine_ops + report.n_ledger + report.n_retry)
        if report.replayed:
            self.failover_stats["replayed"] += (
                report.n_ledger + report.n_retry)
        self.failover_stats["reposted"] += report.n_reposted
        return report


# ---------------------------------------------------------------------------
# Global default runtime (the paper's ``g_runtime`` idiom)
# ---------------------------------------------------------------------------
_RUNTIME: Optional[Runtime] = None


def init(alloc_default_resources: bool = True,
         default_axis: Optional[str] = None) -> Runtime:
    """Initialize the global default LCX runtime (idempotent re-init
    replaces it).  Explicit ``init()`` works even under
    ``LCX_NO_GLOBAL_RUNTIME=1`` — the flag only disables *lazy*
    auto-creation via :func:`runtime`."""
    global _RUNTIME
    _RUNTIME = Runtime(alloc_default_resources=alloc_default_resources,
                       default_axis=default_axis, name="g_runtime")
    return _RUNTIME


def finalize(strict: bool = True, runtime: Optional[Runtime] = None) -> None:
    """Tear down a runtime with a leak check.  Without ``runtime``,
    finalizes and clears the global default instance; with one, finalizes
    that runtime only (the global, if any, is untouched)."""
    global _RUNTIME
    if runtime is not None:
        runtime.finalize(strict=strict)
        if runtime is _RUNTIME:
            _RUNTIME = None
        return
    if _RUNTIME is not None:
        rt, _RUNTIME = _RUNTIME, None
        rt.finalize(strict=strict)


def runtime() -> Runtime:
    """The global default runtime, lazily created on first use.  Set
    ``LCX_NO_GLOBAL_RUNTIME=1`` to disable lazy creation and require
    explicit :func:`init` / injected ``Runtime`` objects everywhere."""
    global _RUNTIME
    if _RUNTIME is None:
        if os.environ.get("LCX_NO_GLOBAL_RUNTIME", "") not in ("", "0"):
            raise RuntimeError(
                "LCX_NO_GLOBAL_RUNTIME is set: the global default runtime "
                "is disabled. Call lcx.init() explicitly or pass a Runtime "
                "via .runtime(rt)/.endpoint(ep).")
        _RUNTIME = Runtime(name="g_runtime")
    return _RUNTIME


# Internal alias: lets code with a ``runtime=None`` *parameter* still
# reach the module-level accessor without shadowing.
_global_runtime = runtime


def install_transport(
        transport: Optional[FaultyTransport],
        runtime: Optional[Runtime] = None) -> Optional[FaultyTransport]:
    """Install (or, with ``None``, remove) a fault-injecting transport on
    a runtime: sets the runtime-wide fallback AND delegates to every
    device currently attached (per-device installs override the
    fallback; use :meth:`Device.install_transport` directly for
    single-device chaos).  Defaults to the global runtime.  Returns the
    previous runtime-wide transport."""
    rt = runtime if runtime is not None else _global_runtime()
    prev, rt.transport = rt.transport, transport
    for dev in rt.devices():
        dev.install_transport(transport)
    return prev


# ---------------------------------------------------------------------------
# Resource resolution (endpoint → device → net context → runtime defaults)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ResolvedResources:
    """The concrete resource set a posting op runs against, resolved by
    :func:`resolve_resources` from whatever handles the caller supplied."""
    runtime: Runtime
    device: Optional[Device]
    endpoint: Optional[Endpoint]
    engine: Optional[MatchingEngine]
    pool: Optional[PacketPool]
    cq: Optional[CompletionQueue]


def resolve_resources(runtime: Optional[Runtime] = None,
                      endpoint: Optional[Endpoint] = None,
                      device: Optional[Device] = None,
                      engine: Optional[MatchingEngine] = None,
                      pool: Optional[PacketPool] = None,
                      ) -> ResolvedResources:
    """Single resolution path for every posting op (paper §2.2: "an
    operation resolves its resources most-specific-first").

    Precedence, per resource: explicit argument > endpoint > device >
    runtime defaults.  The owning runtime is found by walking up the
    hierarchy (endpoint → device → net context → runtime); a *floating*
    device (bare ``Device(...)``, no hierarchy parent) resolves engine/
    pool from the ambient runtime's defaults — the legacy shared-pool
    behaviour that lets two bare devices on one axis still match.
    """
    if endpoint is not None and device is not None \
            and endpoint.device is not device:
        raise ValueError(
            f"endpoint {endpoint!r} belongs to {endpoint.device!r}, "
            f"not the explicitly passed {device!r}")
    if endpoint is not None and device is None:
        device = endpoint.device
    if device is not None and device.migrated_to is not None:
        # stale handle to a failed-over device: forward to the survivor
        device = device.resolve_migrated()
    rt = runtime
    if rt is None and device is not None:
        rt = device.runtime          # None when the device floats
    if rt is None:
        rt = _global_runtime()
    if device is None:
        device = rt.default_device
    ep = endpoint
    if ep is None and device is not None:
        ep = device.default_endpoint  # None for floating devices
    if engine is None:
        engine = ep.engine if ep is not None else None
    if engine is None and device is not None:
        engine = device.engine
    if engine is None:
        engine = rt.default_engine
    if pool is None:
        pool = ep.pool if ep is not None else None
    if pool is None and device is not None:
        pool = device.pool
    if pool is None:
        pool = rt.default_pool
    cq = ep.cq if ep is not None else None
    if cq is None and device is not None:
        cq = device.cq
    if cq is None:
        cq = rt.default_cq
    return ResolvedResources(runtime=rt, device=device, endpoint=ep,
                             engine=engine, pool=pool, cq=cq)
