"""LCX resources (paper §2.2).

The interface consists of *resources* and *operations*.  Major resources:

- :class:`Device` — encapsulates the low-level network resource.  On TPU
  the "network" is the ICI mesh accessed through compiled collectives;
  a Device names a mesh axis (its communicator) plus a backend and
  tunable attributes.
- :class:`PacketPool` — pre-registered fixed-size internal buffers.  At
  the JAX level the pool enables *message aggregation*: many fine-grained
  eager-protocol messages are packed into one transfer (the TPU analogue
  of doorbell batching / packet reuse).
- :class:`MatchingEngine` — matches sends with receives.  Two
  implementations (``queue`` in-order, ``map`` keyed) and five policies
  (``none``, ``rank_only``, ``tag_only``, ``rank_tag``, ``custom``).
- Completion objects — :class:`Synchronizer`, :class:`CompletionQueue`,
  :class:`FunctionHandler`; all subclassable via ``signal()``.

Resources map to operations independently: two operations may share a
device but use different completion objects; sends and recvs posted on
*different devices* still match if they share a matching engine.

Execution model (hardware adaptation, see DESIGN.md §2): LCI posts
operations at *runtime* from many threads; LCX posts at *trace time*
inside one SPMD program.  Posted operations are pended; the
:func:`~repro.core.ops.progress` operation resolves matches and
materializes transfers as ``lax.ppermute``/``lax.all_to_all`` ops (or
Pallas remote-DMA kernels), then signals completion objects.  Completion
is data availability of the traced value.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attr import HasAttrs

# Interface constants (paper §2.2): immediate-data-constrained limits for
# put-with-remote-signal; full-width limits elsewhere.
IMMEDIATE_TAG_BITS = 16
IMMEDIATE_RCOMP_BITS = 15
MAX_TAG_BITS = 64
MAX_RCOMP_BITS = 32


# ---------------------------------------------------------------------------
# Permutation specs (who talks to whom on a device's axis)
# ---------------------------------------------------------------------------
class Perm:
    """A trace-time communication pattern on a device axis.

    In SPMD there is no runtime "destination rank" argument; the pattern
    *is* the argument.  ``Perm.shift(1)`` is the ring successor,
    ``Perm.pairs([(0, 3)])`` a single point-to-point message (other ranks
    carry padding), ``Perm.all_to(r)``/``Perm.from_(r)`` fan-in/fan-out.
    """

    def __init__(self, fn: Callable[[int], List[Tuple[int, int]]], name: str):
        self._fn = fn
        self.name = name
        # Per-axis_size memo: the progress engine re-derives pairs/keys on
        # every post and every transfer, so these are hot-path lookups.
        self._pairs_memo: Dict[int, List[Tuple[int, int]]] = {}
        self._key_memo: Dict[int, Tuple[Tuple[int, int], ...]] = {}

    def pairs_for(self, axis_size: int) -> List[Tuple[int, int]]:
        pairs = self._pairs_memo.get(axis_size)
        if pairs is None:
            pairs = self._pairs_memo[axis_size] = self._fn(axis_size)
        return pairs

    # -- constructors -------------------------------------------------------
    @staticmethod
    def shift(k: int) -> "Perm":
        return Perm(lambda n: [(i, (i + k) % n) for i in range(n)],
                    f"shift({k})")

    @staticmethod
    def pairs(ps: Sequence[Tuple[int, int]]) -> "Perm":
        ps = [tuple(p) for p in ps]
        return Perm(lambda n: list(ps), f"pairs({ps})")

    @staticmethod
    def to(dst: int, src: int) -> "Perm":
        return Perm.pairs([(src, dst)])

    def key(self, axis_size: int) -> Tuple[Tuple[int, int], ...]:
        key = self._key_memo.get(axis_size)
        if key is None:
            key = self._key_memo[axis_size] = tuple(
                sorted(self.pairs_for(axis_size)))
        return key

    def inverse(self) -> "Perm":
        fn = self._fn
        return Perm(lambda n: [(d, s) for (s, d) in fn(n)],
                    f"inv({self.name})")

    def __repr__(self) -> str:
        return f"Perm<{self.name}>"


# ---------------------------------------------------------------------------
# Completion objects
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class Event:
    """A completion event delivered to a completion object."""

    payload: Any = None          # traced array (recv/get/am/put-target side)
    op: str = ""                 # "send"|"recv"|"put"|"get"|"am"
    tag: int = 0
    perm: Optional[Perm] = None
    remote: bool = False         # True when this is a *remote* completion
    context: Any = None          # user context passed at post time


class CompletionObject(HasAttrs):
    """Base completion object.  Users may subclass and override
    :meth:`signal` to customize completion semantics (paper: e.g. an
    atomic-counter object waiting for all previously posted ops)."""

    _ATTR_DEFAULTS: Dict[str, Any] = {}

    def __init__(self, **attrs: Any) -> None:
        self._init_attrs(attrs)

    def signal(self, event: Event) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    # Default-resource bookkeeping
    def __repr__(self) -> str:
        return f"{type(self).__name__}@{id(self):x}"


class Synchronizer(CompletionObject):
    """MPI-request-like object that can wait for *multiple* completed
    operations before becoming ready (paper §2.2)."""

    _ATTR_DEFAULTS = {"threshold": 1}

    def __init__(self, threshold: Optional[int] = None, **attrs: Any) -> None:
        super().__init__(threshold=threshold, **attrs)
        self._events: List[Event] = []

    def signal(self, event: Event) -> None:
        self._events.append(event)

    @property
    def threshold(self) -> int:
        return self._attrs["threshold"]

    def ready(self) -> bool:
        return len(self._events) >= self.threshold

    def wait(self, reset: bool = True) -> List[Event]:
        """Return the completed events.  In trace-time LCX, ops complete
        at ``progress()``; waiting before enough progress is a program
        error (there is no background thread to make it ready)."""
        if not self.ready():
            raise RuntimeError(
                f"Synchronizer.wait(): only {len(self._events)} of "
                f"{self.threshold} completions arrived — call "
                "lcx.progress() after posting"
            )
        events, rest = (self._events[: self.threshold],
                        self._events[self.threshold:])
        if reset:
            self._events = rest
        return events

    def wait_payloads(self, reset: bool = True) -> List[Any]:
        return [e.payload for e in self.wait(reset=reset)]


class CompletionQueue(CompletionObject):
    """FIFO completion queue."""

    _ATTR_DEFAULTS = {"capacity": 1 << 16}

    def __init__(self, capacity: Optional[int] = None, **attrs: Any) -> None:
        super().__init__(capacity=capacity, **attrs)
        self._q: deque = deque()

    def signal(self, event: Event) -> None:
        if len(self._q) >= self._attrs["capacity"]:
            raise RuntimeError("CompletionQueue overflow")
        self._q.append(event)

    def pop(self) -> Optional[Event]:
        return self._q.popleft() if self._q else None

    def pop_all(self) -> List[Event]:
        out = list(self._q)
        self._q.clear()
        return out

    def __len__(self) -> int:
        return len(self._q)


class FunctionHandler(CompletionObject):
    """Completion object that invokes a function on each event — the
    active-message handler, usable as *local or remote* completion for any
    operation (paper: "LCI's active message operation supports remote
    completion objects of any type")."""

    def __init__(self, fn: Callable[[Event], Any], **attrs: Any) -> None:
        super().__init__(**attrs)
        self._fn = fn
        self.results: List[Any] = []

    def signal(self, event: Event) -> None:
        self.results.append(self._fn(event))


class CounterCompletion(CompletionObject):
    """Example of the paper's "overload ``signal`` with an atomic counter"
    pattern: becomes ready when N ops completed, keeps no payloads."""

    _ATTR_DEFAULTS = {"target": 1}

    def __init__(self, target: Optional[int] = None, **attrs: Any) -> None:
        super().__init__(target=target, **attrs)
        self.count = 0

    def signal(self, event: Event) -> None:
        self.count += 1

    def ready(self) -> bool:
        return self.count >= self._attrs["target"]


# ---------------------------------------------------------------------------
# Matching engine
# ---------------------------------------------------------------------------
_NO_KEY = object()          # sentinel: match key not yet computed


@dataclasses.dataclass(eq=False)
class PostedOp:
    """A pending posted operation (trace-time analogue of an LCI
    communication descriptor)."""

    kind: str                    # "send" | "recv"
    buffer: Any                  # send: traced array; recv: ShapeDtype proto
    perm: Optional[Perm]
    tag: int
    comp: Optional[CompletionObject]
    device: "Device"
    seq: int
    context: Any = None
    remote_comp: Optional[CompletionObject] = None
    op_name: str = "send"        # original op: send/put/get/am
    allow_aggregation: bool = True
    # Match key, computed ONCE at post time by the matching engine the op
    # is posted to (it depends on the engine's policy).  _NO_KEY until then.
    match_key: Any = _NO_KEY


class MatchingEngine(HasAttrs):
    """Matches posted sends with posted recvs.

    ``kind='map'`` matches on a key derived from the policy, regardless of
    posting order (the multithreaded-throughput implementation in the
    paper — LCI attributes its message-rate advantage to hash-table tag
    matching, and this engine mirrors that: keyed hash buckets give O(1)
    amortized post+match instead of the O(S×R) pending-list scan).
    ``kind='queue'`` only matches in FIFO order (in-order receives): a
    send matches the *head* recv and vice versa; a key mismatch at the
    heads leaves both pending (they may match after reordering posts —
    which, trace-time, means user error surfaced by ``flush``).

    Map-mode invariant: after every ``post`` no matchable (send, recv)
    pair remains pending, so a new op can only match the *oldest*
    pending opposite op with the same key — which is exactly the head of
    that key's bucket.  Custom ``key_fn``s returning unhashable keys
    fall back to a linear bucket scan with identical semantics.
    """

    _ATTR_DEFAULTS = {"kind": "map", "policy": "rank_tag"}
    POLICIES = ("none", "rank_only", "tag_only", "rank_tag", "custom")

    def __init__(self, kind: Optional[str] = None,
                 policy: Optional[str] = None,
                 key_fn: Optional[Callable[[PostedOp], Any]] = None,
                 **attrs: Any) -> None:
        self._init_attrs({"kind": kind, "policy": policy, **attrs})
        if self._attrs["kind"] not in ("map", "queue"):
            raise ValueError(f"unknown matching engine kind "
                             f"{self._attrs['kind']!r}")
        if self._attrs["policy"] not in self.POLICIES:
            raise ValueError(f"unknown match policy {self._attrs['policy']!r}")
        if self._attrs["policy"] == "custom" and key_fn is None:
            raise ValueError("custom match policy requires key_fn")
        self._key_fn = key_fn
        # queue kind: FIFO deques.  map kind: key -> deque buckets, plus
        # an unhashable-key overflow list ((key, op) pairs, linear scan).
        self._pending_send: deque = deque()
        self._pending_recv: deque = deque()
        self._send_buckets: Dict[Any, deque] = {}
        self._recv_buckets: Dict[Any, deque] = {}
        self._send_overflow: List[Tuple[Any, PostedOp]] = []
        self._recv_overflow: List[Tuple[Any, PostedOp]] = []
        self._n_send = 0
        self._n_recv = 0
        self.n_matched = 0

    # -- key derivation ------------------------------------------------------
    def _key(self, op: PostedOp) -> Any:
        """Derive (and cache on the op) the policy match key.  Computed
        once at post time; the cached value is reused on every later
        drain attempt instead of re-deriving perm keys in inner loops."""
        if op.match_key is not _NO_KEY:
            return op.match_key
        policy = self._attrs["policy"]
        if policy == "none":
            key = ()
        elif policy == "rank_only":
            key = op.perm.key(op.device.axis_size) if op.perm else ()
        elif policy == "tag_only":
            key = op.tag
        elif policy == "rank_tag":
            key = ((op.perm.key(op.device.axis_size) if op.perm else ()),
                   op.tag)
        else:
            key = self._key_fn(op)
        op.match_key = key
        return key

    # -- posting ---------------------------------------------------------------
    def post(self, op: PostedOp) -> List[Tuple[PostedOp, PostedOp]]:
        """Post an op; return newly formed (send, recv) matches."""
        if self._attrs["kind"] == "queue":
            if op.kind == "send":
                self._pending_send.append(op)
            else:
                self._pending_recv.append(op)
            return self._drain_queue()
        return self._post_map(op)

    def _post_map(self, op: PostedOp) -> List[Tuple[PostedOp, PostedOp]]:
        key = self._key(op)
        is_send = op.kind == "send"
        other_buckets = self._recv_buckets if is_send else self._send_buckets
        other_overflow = self._recv_overflow if is_send else self._send_overflow
        try:
            bucket = other_buckets.get(key)
        except TypeError:                     # unhashable custom key
            return self._post_map_unhashable(op, key)
        peer: Optional[PostedOp] = None
        if bucket:
            peer = bucket.popleft()
            if not bucket:
                del other_buckets[key]
        elif other_overflow:
            # hashable key may still match an unhashable-keyed peer via ==
            for i, (okey, oop) in enumerate(other_overflow):
                if okey == key:
                    peer = oop
                    del other_overflow[i]
                    break
        if peer is None:
            own = self._send_buckets if is_send else self._recv_buckets
            own.setdefault(key, deque()).append(op)
            if is_send:
                self._n_send += 1
            else:
                self._n_recv += 1
            return []
        if is_send:
            self._n_recv -= 1
            match = (op, peer)
        else:
            self._n_send -= 1
            match = (peer, op)
        self.n_matched += 1
        return [match]

    def _post_map_unhashable(self, op: PostedOp,
                             key: Any) -> List[Tuple[PostedOp, PostedOp]]:
        is_send = op.kind == "send"
        other_buckets = self._recv_buckets if is_send else self._send_buckets
        other_overflow = self._recv_overflow if is_send else self._send_overflow
        peer: Optional[PostedOp] = None
        # oldest matching peer across bucketed and overflow pendings
        best_seq = None
        best_loc: Any = None
        for bkey, bucket in other_buckets.items():
            if bkey == key and bucket:
                head = bucket[0]
                if best_seq is None or head.seq < best_seq:
                    best_seq, best_loc, peer = head.seq, ("b", bkey), head
        for i, (okey, oop) in enumerate(other_overflow):
            if okey == key and (best_seq is None or oop.seq < best_seq):
                best_seq, best_loc, peer = oop.seq, ("o", i), oop
        if peer is None:
            own = self._send_overflow if is_send else self._recv_overflow
            own.append((key, op))
            if is_send:
                self._n_send += 1
            else:
                self._n_recv += 1
            return []
        if best_loc[0] == "b":
            bucket = other_buckets[best_loc[1]]
            bucket.popleft()
            if not bucket:
                del other_buckets[best_loc[1]]
        else:
            del other_overflow[best_loc[1]]
        if is_send:
            self._n_recv -= 1
            match = (op, peer)
        else:
            self._n_send -= 1
            match = (peer, op)
        self.n_matched += 1
        return [match]

    def _drain_queue(self) -> List[Tuple[PostedOp, PostedOp]]:
        matches: List[Tuple[PostedOp, PostedOp]] = []
        while self._pending_send and self._pending_recv:
            s, r = self._pending_send[0], self._pending_recv[0]
            if self._key(s) != self._key(r):
                break
            self._pending_send.popleft()
            self._pending_recv.popleft()
            matches.append((s, r))
        self.n_matched += len(matches)
        return matches

    def pending(self) -> Tuple[int, int]:
        if self._attrs["kind"] == "queue":
            return len(self._pending_send), len(self._pending_recv)
        return self._n_send, self._n_recv


# ---------------------------------------------------------------------------
# Packet pool
# ---------------------------------------------------------------------------
class PacketPool(HasAttrs):
    """Pre-registered fixed-size buffer pool.

    Messages with ``nbytes <= packet_size`` travel the *eager* path and
    are eligible for aggregation: at progress time all eager messages
    sharing a (axis, perm) pattern are packed into one transfer.  Larger
    messages take the *rendezvous* path (their own transfer) — mirroring
    LCI's eager/rendezvous split.
    """

    _ATTR_DEFAULTS = {"npackets": 4096, "packet_size": 65536,
                      "aggregate": True}

    def __init__(self, npackets: Optional[int] = None,
                 packet_size: Optional[int] = None, **attrs: Any) -> None:
        self._init_attrs(
            {"npackets": npackets, "packet_size": packet_size, **attrs})
        self.stats = {"eager_msgs": 0, "rendezvous_msgs": 0,
                      "aggregated_transfers": 0, "raw_transfers": 0}

    def is_eager(self, nbytes: int) -> bool:
        return nbytes <= self._attrs["packet_size"]


# ---------------------------------------------------------------------------
# Device
# ---------------------------------------------------------------------------
class Device(HasAttrs):
    """The per-communicator network resource.

    ``axis`` names the mesh axis this device communicates over (its
    "NIC port" onto the ICI torus); ``axis=None`` is the loopback/sim
    device used for single-process semantics tests.  Multiple devices on
    the same axis model LCI's device-per-thread isolation: their pending
    traffic is progressed independently (separate transfer schedules).
    """

    _ATTR_DEFAULTS = {
        "axis": None,            # mesh axis name (str) or None = loopback
        "backend": "xla",        # "xla" | "pallas" (TPU-only) | "sim"
        "max_inflight": 64,       # max transfers materialized per progress
        "allow_payload_metadata": True,
        "mesh_shape": None,       # optional dict axis->size when not in ctx
    }

    def __init__(self, axis: Optional[str] = None, **attrs: Any) -> None:
        self._init_attrs({"axis": axis, **attrs})
        self.stats = {"posted": 0, "transfers": 0, "progressed": 0,
                      "bytes_moved": 0}

    @property
    def axis(self) -> Optional[str]:
        return self._attrs["axis"]

    @property
    def axis_size(self) -> int:
        axis = self.axis
        if axis is None:
            return 1
        ms = self._attrs.get("mesh_shape")
        if ms and axis in ms:
            return int(ms[axis])
        # Inside shard_map the axis is bound; query its size.
        from repro.compat import axis_size
        try:
            return axis_size(axis)
        except NameError:
            raise RuntimeError(
                f"Device axis {axis!r} is not bound — post LCX ops under "
                "shard_map over that axis, or pass mesh_shape attr"
            )


# ---------------------------------------------------------------------------
# Memory registration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class MemoryRegion:
    """Explicit memory registration (paper §2.2: reuse registrations to
    reduce overhead).  In XLA the analogue of registration cost is layout/
    donation setup; we track reuse so benchmarks can report it."""

    array: Any
    registration_id: int
    uses: int = 0


# ---------------------------------------------------------------------------
# Runtime (default resources + pending transfer ledger)
# ---------------------------------------------------------------------------
class Runtime:
    """Holds default resources and the pending-transfer ledger.

    The paper: "There will be a default set of resources allocated by the
    runtime.  Users only need to explicitly manage resources when they
    find it necessary.  Users can also disable this default resource
    allocation."
    """

    def __init__(self, alloc_default_resources: bool = True,
                 default_axis: Optional[str] = None) -> None:
        self._seq = itertools.count()
        self._reg_ids = itertools.count(1)
        self.default_device: Optional[Device] = None
        self.default_pool: Optional[PacketPool] = None
        self.default_engine: Optional[MatchingEngine] = None
        self.default_cq: Optional[CompletionQueue] = None
        if alloc_default_resources:
            self.default_device = Device(axis=default_axis)
            self.default_pool = PacketPool()
            self.default_engine = MatchingEngine()
            self.default_cq = CompletionQueue()
        # (send, recv) matches waiting for a progress() call, ledgered
        # per device so take_ready(device) is an O(1) dict pop instead of
        # a quadratic filter over one global list.  A cross-device match
        # (shared engine, different devices) is indexed under BOTH
        # devices; entries are [match, taken] cells so whichever ledger
        # is drained first claims the match.
        self._ready: Dict[int, List[List[Any]]] = {}
        self._n_pending = 0
        # Aggregation-plan cache: (axis, perm-key, dtype-sig, shape-sig)
        # -> concat/slice layout, reused across progress calls so
        # steady-state loops don't re-derive pack/unpack plans.
        self.agg_plans: Dict[Any, Any] = {}
        self.plan_stats: Dict[str, int] = {"hits": 0, "misses": 0}
        self._rcomp_registry: Dict[int, CompletionObject] = {}
        self._rcomp_next = itertools.count(1)
        self._lock = threading.Lock()

    # -- sequencing ---------------------------------------------------------
    def next_seq(self) -> int:
        return next(self._seq)

    # -- remote completion registry ------------------------------------------
    def register_rcomp(self, comp: CompletionObject) -> int:
        rid = next(self._rcomp_next)
        if rid >= (1 << MAX_RCOMP_BITS):
            raise RuntimeError("remote completion handler space exhausted")
        self._rcomp_registry[rid] = comp
        return rid

    def rcomp(self, rid: int) -> CompletionObject:
        return self._rcomp_registry[rid]

    # -- memory registration ---------------------------------------------------
    def register_memory(self, array: Any) -> MemoryRegion:
        return MemoryRegion(array=array, registration_id=next(self._reg_ids))

    # -- match ledger -----------------------------------------------------------
    def enqueue_matches(
            self, matches: List[Tuple[PostedOp, PostedOp]]) -> None:
        for m in matches:
            entry = [m, False]
            d0 = id(m[0].device)
            self._ready.setdefault(d0, []).append(entry)
            d1 = id(m[1].device)
            if d1 != d0:
                self._ready.setdefault(d1, []).append(entry)
            self._n_pending += 1

    def take_ready(self, device: Optional[Device] = None
                   ) -> List[Tuple[PostedOp, PostedOp]]:
        out: List[Tuple[PostedOp, PostedOp]] = []
        if device is None:
            for ledger in self._ready.values():
                for entry in ledger:
                    if not entry[1]:
                        entry[1] = True
                        out.append(entry[0])
            self._ready.clear()
        else:
            for entry in self._ready.pop(id(device), ()):
                if not entry[1]:
                    entry[1] = True
                    out.append(entry[0])
        self._n_pending -= len(out)
        return out

    def pending_count(self) -> int:
        return self._n_pending


_RUNTIME: Optional[Runtime] = None


def init(alloc_default_resources: bool = True,
         default_axis: Optional[str] = None) -> Runtime:
    """Initialize the LCX runtime (idempotent re-init replaces it)."""
    global _RUNTIME
    _RUNTIME = Runtime(alloc_default_resources=alloc_default_resources,
                       default_axis=default_axis)
    return _RUNTIME


def finalize(strict: bool = True) -> None:
    global _RUNTIME
    if _RUNTIME is not None and strict and _RUNTIME.pending_count():
        raise RuntimeError(
            f"lcx.finalize(): {_RUNTIME.pending_count()} matched transfers "
            "never progressed")
    _RUNTIME = None


def runtime() -> Runtime:
    global _RUNTIME
    if _RUNTIME is None:
        _RUNTIME = Runtime()
    return _RUNTIME
