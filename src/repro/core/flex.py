"""The *objectized flexible function* idiom (paper §3.1, Listing 1.1).

The paper replaces C function definitions with C++ classes whose
constructor takes the positional arguments, whose chainable methods set
optional arguments (in any order), and whose ``operator()`` invokes the
operation::

    D d = foo_x(a1).c(c1)();

``FlexOp`` is the Python realization.  A subclass declares its signature
declaratively::

    class send_x(FlexOp):
        _positional = ("buffer",)
        _optional = dict(tag=0, to=None, comp=None, device=None,
                         matching_engine=None)
        def _invoke(self): ...

and callers write ``send_x(buf).tag(3).comp(cq)()``.  Setters mutate and
return ``self`` so an op object can be **reused** across calls without
re-passing unchanged arguments — the paper calls this out as an explicit
advantage of the idiom.  ``clone()`` gives an independent copy when reuse
must not alias.

Every flex op also gets a plain-function shorthand via :func:`plain`,
matching the binding guideline "[each op] also defines a normal C++
function with all positional arguments to simplify programming in the
simple case".
"""
from __future__ import annotations

import copy
from typing import Any, Callable, Dict, Tuple


class _Required:
    """Sentinel for optional-args that must be set before invocation."""

    def __repr__(self) -> str:  # pragma: no cover
        return "<required>"


REQUIRED = _Required()


def _make_setter(name: str) -> Callable[["FlexOp", Any], "FlexOp"]:
    def setter(self: "FlexOp", value: Any) -> "FlexOp":
        self._args[name] = value
        return self

    setter.__name__ = name
    setter.__qualname__ = name
    setter.__doc__ = f"Set optional argument ``{name}`` and return self."
    return setter


class FlexOp:
    """Base class for objectized flexible functions.

    Subclasses declare ``_positional`` (tuple of names) and ``_optional``
    (dict name -> default, or :data:`REQUIRED`), and implement
    ``_invoke()`` which may read every argument via ``self.arg(name)``.
    """

    _positional: Tuple[str, ...] = ()
    _optional: Dict[str, Any] = {}

    def __init_subclass__(cls, **kw: Any) -> None:
        super().__init_subclass__(**kw)
        for name in cls._optional:
            if name in cls._positional:
                raise TypeError(
                    f"{cls.__name__}: argument {name!r} is both positional "
                    "and optional"
                )
            # Do not clobber a hand-written setter/override.
            if name not in cls.__dict__:
                setattr(cls, name, _make_setter(name))

    def __init__(self, *args: Any, **kwargs: Any) -> None:
        cls = type(self)
        if len(args) > len(cls._positional):
            raise TypeError(
                f"{cls.__name__} takes {len(cls._positional)} positional "
                f"arguments ({', '.join(cls._positional)}), got {len(args)}"
            )
        self._args: Dict[str, Any] = dict(cls._optional)
        for name, value in zip(cls._positional, args):
            self._args[name] = value
        for name in cls._positional[len(args):]:
            self._args.setdefault(name, REQUIRED)
        for name, value in kwargs.items():
            if name not in cls._optional and name not in cls._positional:
                raise TypeError(f"{cls.__name__}: unknown argument {name!r}")
            self._args[name] = value

    # -- argument access ---------------------------------------------------
    def arg(self, name: str) -> Any:
        value = self._args[name]
        if value is REQUIRED:
            raise TypeError(
                f"{type(self).__name__}: required argument {name!r} was "
                "never set"
            )
        return value

    def arg_or(self, name: str, default: Any) -> Any:
        value = self._args.get(name, REQUIRED)
        return default if value is REQUIRED or value is None else value

    def is_set(self, name: str) -> bool:
        return self._args.get(name, REQUIRED) is not REQUIRED

    # -- reuse -------------------------------------------------------------
    def clone(self) -> "FlexOp":
        new = copy.copy(self)
        new._args = dict(self._args)
        return new

    # -- invocation --------------------------------------------------------
    def __call__(self, **late: Any) -> Any:
        """Invoke the operation.  Late keyword overrides are applied to a
        *temporary* copy so the op object stays reusable."""
        if late:
            return self._call_with(late)
        return self._invoke()

    def _call_with(self, late: Dict[str, Any]) -> Any:
        tmp = self.clone()
        for name, value in late.items():
            if name not in type(self)._optional and name not in type(self)._positional:
                raise TypeError(f"{type(self).__name__}: unknown argument {name!r}")
            tmp._args[name] = value
        return tmp._invoke()

    def _invoke(self) -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    def __repr__(self) -> str:
        cls = type(self)
        parts = []
        for name in (*cls._positional, *cls._optional):
            v = self._args.get(name, REQUIRED)
            parts.append(f"{name}={'<unset>' if v is REQUIRED else v!r}")
        return f"{cls.__name__}({', '.join(parts)})"


def plain(flex_cls: type) -> Callable[..., Any]:
    """Derive the plain-function shorthand for a flex-op class.

    ``send = plain(send_x)`` gives ``send(buf, tag=3)`` ==
    ``send_x(buf).tag(3)()``.
    """

    def fn(*args: Any, **kwargs: Any) -> Any:
        return flex_cls(*args, **kwargs)()

    fn.__name__ = flex_cls.__name__.removesuffix("_x")
    fn.__doc__ = f"Plain-function shorthand for {flex_cls.__name__}."
    return fn
