"""Attribute system (paper §2.2).

Every resource has a set of tunable parameters called *attributes*.
Defaults are specified at global scope (here: env vars ``LCX_ATTR_<NAME>``
or :func:`set_global_attr`), and per-resource values are given at
allocation time.  Resources expose ``get_attr_<name>()`` query methods —
implemented once here via ``__getattr__`` dispatch on :class:`HasAttrs`.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, Optional

_GLOBAL_ATTRS: Dict[str, Any] = {}


def set_global_attr(name: str, value: Any) -> None:
    """Set a global default attribute (applies to resources allocated
    after this call)."""
    _GLOBAL_ATTRS[name] = value


def get_global_attr(name: str, default: Any = None) -> Any:
    env = os.environ.get(f"LCX_ATTR_{name.upper()}")
    if env is not None:
        return _parse_env(env)
    return _GLOBAL_ATTRS.get(name, default)


def reset_global_attrs() -> None:
    _GLOBAL_ATTRS.clear()


def _parse_env(s: str) -> Any:
    low = s.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    return s


class HasAttrs:
    """Mixin giving a resource its attribute table and the
    ``get_attr_<name>`` query interface.

    Resolution order at allocation: explicit per-resource value >
    env var ``LCX_ATTR_<NAME>`` > global default > class default.
    """

    _ATTR_DEFAULTS: Dict[str, Any] = {}

    def _init_attrs(self, overrides: Optional[Dict[str, Any]] = None) -> None:
        attrs: Dict[str, Any] = {}
        for name, default in type(self)._ATTR_DEFAULTS.items():
            attrs[name] = get_global_attr(name, default)
        for name, value in (overrides or {}).items():
            if name not in type(self)._ATTR_DEFAULTS:
                raise AttributeError(
                    f"{type(self).__name__} has no attribute {name!r}; "
                    f"known: {sorted(type(self)._ATTR_DEFAULTS)}"
                )
            if value is not None:
                attrs[name] = value
        self._attrs = attrs

    def __getattr__(self, item: str) -> Any:
        if item.startswith("get_attr_"):
            name = item[len("get_attr_"):]
            try:
                value = self._attrs[name]
            except (AttributeError, KeyError):
                raise AttributeError(
                    f"{type(self).__name__} has no attribute {name!r}"
                ) from None

            def getter(_value: Any = value) -> Any:
                return _value

            return getter
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {item!r}"
        )

    def attrs(self) -> Dict[str, Any]:
        return dict(self._attrs)
