"""Collectives built on LCX point-to-point operations.

LCI's position is that AMT communication is point-to-point; collectives
are *library-level* compositions over p2p (the way RCCL/UCC build them
over verbs).  We provide ring algorithms whose every step is an LCX
``put`` with an explicit ``progress()`` placement (the overlap knob), and
a ``native`` backend that lowers to the XLA collective directly so the
two can be compared in the roofline (§Perf iterates on this choice).

All functions must run under ``shard_map`` with the device's axis bound.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .flex import FlexOp, plain
from .resources import (Device, Endpoint, Perm, Runtime, Synchronizer,
                        resolve_resources)
from . import ops as lcx_ops


def _resolve_dev(op: FlexOp) -> tuple:
    """(runtime, device) for a collective op, resolved through the same
    endpoint -> device -> runtime-defaults path as the posting ops."""
    res = resolve_resources(runtime=op.arg_or("runtime", None),
                            endpoint=op.arg_or("endpoint", None),
                            device=op.arg_or("device", None))
    return res.runtime, res.device


def _axis_of(dev: Device) -> str:
    if dev.axis is None:
        raise ValueError("collective needs a device bound to a mesh axis")
    return dev.axis


def _lcx_shift(x: Any, k: int, rt: Runtime, device: Device, tag: int) -> Any:
    """One ring hop expressed as an LCX put + progress + completion."""
    sync = Synchronizer(threshold=1)
    lcx_ops.put_x(x).perm(Perm.shift(k)).tag(tag).remote_comp(sync) \
        .runtime(rt).device(device)()
    lcx_ops.progress_x().runtime(rt).device(device)()
    (ev,) = sync.wait()
    return ev.payload


# ---------------------------------------------------------------------------
# all-gather (ring)
# ---------------------------------------------------------------------------
class all_gather_x(FlexOp):
    """Gather each shard's ``x`` along a new leading axis (then merged into
    dim 0), ring or native backend."""

    _positional = ("x",)
    _optional = dict(device=None, runtime=None, endpoint=None,
                     backend="ring", tiled=True, tag=0)

    def _invoke(self) -> Any:
        x = self.arg("x")
        rt, dev = _resolve_dev(self)
        axis = _axis_of(dev)
        backend = self.arg_or("backend", "ring")
        tiled = self.arg_or("tiled", True)
        if backend == "native":
            return lax.all_gather(x, axis, tiled=tiled)
        n = dev.axis_size
        idx = lax.axis_index(axis)
        buf = jnp.zeros((n,) + x.shape, x.dtype)
        buf = lax.dynamic_update_index_in_dim(buf, x, idx, 0)
        cur = x
        for step in range(n - 1):
            cur = _lcx_shift(cur, 1, rt, dev, self.arg_or("tag", 0))
            src = (idx - step - 1) % n
            buf = lax.dynamic_update_index_in_dim(buf, cur, src, 0)
        if tiled:
            return buf.reshape((n * x.shape[0],) + x.shape[1:]) \
                if x.ndim else buf
        return buf


# ---------------------------------------------------------------------------
# reduce-scatter (ring)
# ---------------------------------------------------------------------------
class reduce_scatter_x(FlexOp):
    """Sum-reduce ``x`` across the axis, leaving each shard with its
    1/N slice of dim 0."""

    _positional = ("x",)
    _optional = dict(device=None, runtime=None, endpoint=None,
                     backend="ring", tag=0)

    def _invoke(self) -> Any:
        x = self.arg("x")
        rt, dev = _resolve_dev(self)
        axis = _axis_of(dev)
        if self.arg_or("backend", "ring") == "native":
            return lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
        n = dev.axis_size
        if x.shape[0] % n:
            raise ValueError(f"reduce_scatter dim0 {x.shape[0]} % {n}")
        idx = lax.axis_index(axis)
        chunks = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        # The accumulator carrying chunk c starts at rank c+1 and moves +1
        # per hop; after n-1 hops it has visited every rank and lands at
        # rank c.  So rank i seeds with its local chunk (i-1) and, at hop
        # s (1-indexed), the arriving accumulator carries chunk (i-s-1),
        # to which we add our local copy.
        acc = lax.dynamic_index_in_dim(chunks, (idx - 1) % n, 0,
                                       keepdims=False)
        for step in range(n - 1):
            acc = _lcx_shift(acc, 1, rt, dev, self.arg_or("tag", 0))
            take = (idx - step - 2) % n
            acc = acc + lax.dynamic_index_in_dim(chunks, take, 0,
                                                 keepdims=False)
        return acc


# ---------------------------------------------------------------------------
# all-reduce = reduce-scatter + all-gather (ring) or native psum
# ---------------------------------------------------------------------------
class all_reduce_x(FlexOp):
    _positional = ("x",)
    _optional = dict(device=None, runtime=None, endpoint=None,
                     backend="ring", tag=0)

    def _invoke(self) -> Any:
        x = self.arg("x")
        rt, dev = _resolve_dev(self)
        axis = _axis_of(dev)
        backend = self.arg_or("backend", "ring")
        if backend == "native":
            return lax.psum(x, axis)
        n = dev.axis_size
        shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        rs = reduce_scatter_x(flat).runtime(rt).device(dev) \
            .backend(backend).tag(self.arg_or("tag", 0))()
        ag = all_gather_x(rs).runtime(rt).device(dev).backend(backend) \
            .tag(self.arg_or("tag", 0) + 1)()
        if pad:
            ag = ag[:-pad]
        return ag.reshape(shape)


# ---------------------------------------------------------------------------
# all-to-all (pairwise LCX puts or native)
# ---------------------------------------------------------------------------
class all_to_all_x(FlexOp):
    """Exchange chunk i of dim 0 with rank i.  ``x`` dim 0 must equal the
    axis size times the chunk size; pairwise backend posts n-1 LCX puts."""

    _positional = ("x",)
    _optional = dict(device=None, runtime=None, endpoint=None,
                     backend="pairwise", tag=0)

    def _invoke(self) -> Any:
        x = self.arg("x")
        rt, dev = _resolve_dev(self)
        axis = _axis_of(dev)
        n = dev.axis_size
        if x.shape[0] % n:
            raise ValueError(f"all_to_all dim0 {x.shape[0]} % {n}")
        if self.arg_or("backend", "pairwise") == "native":
            c = x.shape[0] // n
            xs = x.reshape((n, c) + x.shape[1:])
            out = lax.all_to_all(xs, axis, split_axis=0, concat_axis=0,
                                 tiled=False)
            return out.reshape((n * c,) + x.shape[1:])
        idx = lax.axis_index(axis)
        chunks = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        out = jnp.zeros_like(chunks)
        mine = lax.dynamic_index_in_dim(chunks, idx, 0, keepdims=False)
        out = lax.dynamic_update_index_in_dim(out, mine, idx, 0)
        for k in range(1, n):
            # send the chunk destined for rank (idx+k); receive from (idx-k)
            piece = lax.dynamic_index_in_dim(chunks, (idx + k) % n, 0,
                                             keepdims=False)
            got = _lcx_shift(piece, k, rt, dev, self.arg_or("tag", 0) + k)
            out = lax.dynamic_update_index_in_dim(out, got, (idx - k) % n, 0)
        return out.reshape(x.shape)


class broadcast_x(FlexOp):
    """Broadcast from ``root`` (native masked-psum)."""

    _positional = ("x",)
    _optional = dict(device=None, runtime=None, endpoint=None, root=0)

    def _invoke(self) -> Any:
        x = self.arg("x")
        _, dev = _resolve_dev(self)
        axis = _axis_of(dev)
        idx = lax.axis_index(axis)
        mask = (idx == self.arg_or("root", 0)).astype(x.dtype)
        return lax.psum(x * mask, axis)


def barrier(device: Optional[Device] = None,
            runtime: Optional[Runtime] = None,
            endpoint: Optional[Endpoint] = None) -> None:
    res = resolve_resources(runtime=runtime, endpoint=endpoint, device=device)
    dev = res.device
    if dev is not None and dev.axis is not None:
        lax.psum(jnp.zeros((), jnp.float32), dev.axis)


all_gather = plain(all_gather_x)
reduce_scatter = plain(reduce_scatter_x)
all_reduce = plain(all_reduce_x)
all_to_all = plain(all_to_all_x)
broadcast = plain(broadcast_x)
