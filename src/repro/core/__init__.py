"""LCX — the paper's contribution adapted to JAX/TPU.

A Lightweight Communication Interface for asynchronous many-task
execution inside SPMD JAX programs: resources (Device, PacketPool,
MatchingEngine, completion objects) composed orthogonally with
operations (send/recv, put/get, active messages, progress), expressed
through the *objectized flexible function* idiom.

Typical use (under ``shard_map`` over the device's axis)::

    import repro.core as lcx

    dev  = lcx.Device(axis="model", mesh_shape={"model": 16})
    sync = lcx.Synchronizer(threshold=1)
    lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(sync).device(dev)()
    lcx.progress()
    (ev,) = sync.wait()            # ev.payload == neighbour's x

The AMT client this interface was designed for lives in ``repro.amt``:
a task-graph executor whose worker loop interleaves ready-task
execution with ``progress()`` and retires communication-suspended tasks
from completion objects — the executor's CompletionQueue is drained
after every progress call, FunctionHandlers fired by active messages
enqueue handler tasks, and any completion object with ``ready()``
(Synchronizer, CounterCompletion, custom ``signal`` overloads) can be
watched to resolve promise tasks.  See ``docs/amt.md`` for the
executor ↔ completion-object contract; ``repro.parallel.pipeline`` and
``repro.serving`` are in-repo clients.
"""
from .flex import FlexOp, REQUIRED, plain
from .attr import (get_global_attr, reset_global_attrs, set_global_attr)
from .resources import (CompletionError, CompletionObject, CompletionQueue,
                        CounterCompletion, Device, Endpoint, ErrorCode, Event,
                        FaultPolicy, FaultyTransport, FunctionHandler,
                        MatchingEngine, MemoryRegion, MigrationReport,
                        NetContext, PacketPool,
                        Perm, PostedOp, ResolvedResources, Runtime,
                        Synchronizer, IMMEDIATE_RCOMP_BITS,
                        IMMEDIATE_TAG_BITS, MAX_RCOMP_BITS, MAX_TAG_BITS,
                        finalize, init, install_transport, resolve_resources,
                        runtime, signal_error)
from .ops import (PostHandle, am, am_x, cancel, get, get_x, progress,
                  progress_x, put, put_x, recv, recv_x, register_memory,
                  register_rcomp, send, send_x, sendrecv)
from .collectives import (all_gather, all_gather_x, all_reduce, all_reduce_x,
                          all_to_all, all_to_all_x, barrier, broadcast,
                          broadcast_x, reduce_scatter, reduce_scatter_x)

__all__ = [
    "FlexOp", "REQUIRED", "plain",
    "get_global_attr", "set_global_attr", "reset_global_attrs",
    "CompletionError", "CompletionObject", "CompletionQueue",
    "CounterCompletion", "Device", "Endpoint", "ErrorCode", "Event",
    "FaultPolicy", "FaultyTransport", "FunctionHandler", "MatchingEngine",
    "MemoryRegion", "MigrationReport", "NetContext", "PacketPool", "Perm", "PostedOp",
    "ResolvedResources", "Runtime", "Synchronizer",
    "IMMEDIATE_RCOMP_BITS", "IMMEDIATE_TAG_BITS", "MAX_RCOMP_BITS",
    "MAX_TAG_BITS", "finalize", "init", "install_transport",
    "resolve_resources", "runtime", "signal_error",
    "PostHandle", "am", "am_x", "cancel", "get", "get_x", "progress",
    "progress_x", "put", "put_x", "recv", "recv_x", "register_memory",
    "register_rcomp", "send", "send_x", "sendrecv",
    "all_gather", "all_gather_x", "all_reduce", "all_reduce_x",
    "all_to_all", "all_to_all_x", "barrier", "broadcast", "broadcast_x",
    "reduce_scatter", "reduce_scatter_x",
]
