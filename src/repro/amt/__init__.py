"""AMT — an asynchronous many-task executor layered on LCX.

The paper argues that a lightweight communication interface earns its
keep when an asynchronous many-task runtime drives it.  This package is
that runtime for the repo: :class:`TaskGraph` DAGs of fine-grained
tasks, a completion-driven :class:`Executor` whose worker loop
interleaves task execution with explicit ``lcx.progress()`` and retires
communication-suspended tasks from completion objects (never blocking
waits), and :class:`RemoteSpawner` for shipping named tasks to mesh
neighbours over active messages.

Clients in-repo: the GPipe schedule
(:func:`repro.parallel.pipeline.gpipe`) runs as a task graph whose
inter-stage edges are LCX puts, and the serving engine
(:class:`repro.serving.ServingEngine`) admits prefill/decode work
through an executor.  See ``docs/amt.md`` for the executor ↔
completion-object contract.
"""
from .task import Task, TaskGraph, TaskState
from .executor import (DependencyError, Executor, PENDING, TaskContext,
                       TaskStatus)
from .remote import (RemoteFailure, RemoteSpawner, clear_task_handlers,
                     register_task_handler, task_handler)

__all__ = [
    "Task", "TaskGraph", "TaskState",
    "DependencyError", "Executor", "PENDING", "TaskContext", "TaskStatus",
    "RemoteFailure", "RemoteSpawner", "register_task_handler",
    "task_handler", "clear_task_handlers",
]
