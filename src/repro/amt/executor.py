"""Completion-driven task executor on top of LCX.

This is the runtime the paper's interface was designed *for*: an
asynchronous many-task scheduler whose worker loop interleaves
ready-task execution with explicit ``lcx.progress()`` calls, and which
retires communication-blocked tasks from **completion objects** — a
:class:`~repro.core.resources.CompletionQueue` drained after each
progress call, plus :class:`~repro.core.resources.FunctionHandler`
callbacks fired *by* progress — never from blocking/polling waits.

Execution protocol
------------------
A task body receives a :class:`TaskContext`.  To communicate it posts
LCX operations through the context (``ctx.put`` / ``ctx.am`` /
``ctx.send`` / ``ctx.recv``), which route the operation's completion to
the executor's retirement queue with the task recorded as the event
context.  A body that must wait for arrivals returns
``ctx.suspend(k, n_events=...)``: the task parks as BLOCKED and the
executor calls ``k`` with the event(s) once progress has signalled them,
using ``k``'s return value as the task result.

Backpressure
------------
Admission from the ready heap is gated on the depth of the pending
transfer ledger: when more matched-but-unprogressed transfers are
outstanding than the packet pool has packets (or ``max_inflight``), the
executor drives progress instead of admitting more work — the AMT
analogue of LCI's packet-pool exhaustion pushing back on senders.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, Dict, List, Optional, Tuple

import repro.core as lcx

from .task import Task, TaskGraph, TaskState


class _Pending:
    """Sentinel returned by :meth:`TaskContext.suspend`."""

    def __repr__(self) -> str:  # pragma: no cover
        return "<pending>"


PENDING = _Pending()


@dataclasses.dataclass
class TaskStatus:
    """Per-task fault record kept by the executor in graceful mode.

    ``state`` is ``"ok"`` (never failed), ``"retrying"`` (failed but
    requeued with backoff), ``"failed"`` (retries exhausted, in the
    dead-letter list), or ``"cascade"`` (a dependency failed, so the
    task can never run).
    """

    task: Task
    attempts: int = 0
    state: str = "ok"
    error: Optional[BaseException] = None


class DependencyError(RuntimeError):
    """Raised into a task's error slot when a dependency dead-letters."""


class TaskContext:
    """Handed to every task body; the task's view of the executor."""

    def __init__(self, executor: "Executor", task: Task) -> None:
        self.executor = executor
        self.task = task

    # -- communication posting ----------------------------------------------
    def put(self, buffer: Any, perm: Optional[lcx.Perm] = None, *,
            tag: int = 0, device: Optional[lcx.Device] = None,
            allow_aggregation: bool = True, timeout: Optional[int] = None,
            max_retries: int = 0) -> None:
        """Post a one-sided put whose *remote* completion retires through
        the executor (the receiving side's suspended task resumes)."""
        ex = self.executor
        dev = device or ex.device
        lcx.put_x(buffer).perm(perm).tag(tag) \
            .remote_comp(ex.cq).ctx(self.task) \
            .runtime(ex._runtime).endpoint(None if device else ex.endpoint) \
            .device(dev).allow_aggregation(allow_aggregation) \
            .timeout(timeout).max_retries(max_retries)()
        ex._note_post()

    def am(self, buffer: Any, perm: Optional[lcx.Perm] = None, *,
           tag: int = 0, remote_comp: Optional[Any] = None,
           context: Any = None,
           device: Optional[lcx.Device] = None) -> None:
        """Post an active message.  Defaults the remote completion to the
        executor's retirement queue with this task as context."""
        ex = self.executor
        dev = device or ex.device
        lcx.am_x(buffer).perm(perm).tag(tag) \
            .remote_comp(remote_comp or ex.cq) \
            .runtime(ex._runtime).endpoint(None if device else ex.endpoint) \
            .ctx(self.task if context is None else context).device(dev)()
        ex._note_post()

    def send(self, buffer: Any, perm: Optional[lcx.Perm] = None, *,
             tag: int = 0, device: Optional[lcx.Device] = None,
             timeout: Optional[int] = None, max_retries: int = 0) -> None:
        ex = self.executor
        dev = device or ex.device
        lcx.send_x(buffer).perm(perm).tag(tag).comp(ex.cq) \
            .ctx(self.task).device(dev) \
            .runtime(ex._runtime).endpoint(None if device else ex.endpoint) \
            .timeout(timeout).max_retries(max_retries)()
        ex._note_post()

    def recv(self, like: Any, perm: Optional[lcx.Perm] = None, *,
             tag: int = 0, device: Optional[lcx.Device] = None,
             timeout: Optional[int] = None, max_retries: int = 0) -> None:
        ex = self.executor
        dev = device or ex.device
        lcx.recv_x(like).perm(perm).tag(tag).comp(ex.cq) \
            .ctx(self.task).device(dev) \
            .runtime(ex._runtime).endpoint(None if device else ex.endpoint) \
            .timeout(timeout).max_retries(max_retries)()
        ex._note_post()

    # -- suspension ----------------------------------------------------------
    def suspend(self, k: Optional[Callable[..., Any]] = None,
                n_events: int = 1) -> _Pending:
        """Park this task until ``n_events`` completion events with this
        task as context have been retired; then run ``k(event)`` (or
        ``k(events)`` for n_events > 1) as the task result."""
        self.task._suspension = {"k": k, "need": int(n_events),
                                 "events": []}
        return PENDING

    # -- dynamic graph growth -------------------------------------------------
    def spawn(self, fn: Callable[..., Any], *, deps: Tuple[Task, ...] = (),
              priority: int = 0, name: Optional[str] = None) -> Task:
        return self.executor.spawn(fn, deps=deps, priority=priority,
                                   name=name)


class Executor:
    """Single-threaded (per-rank) completion-driven task scheduler.

    One executor per SPMD rank trace.  Tasks run in priority order
    (higher first, FIFO within a priority); communication-suspended
    tasks retire from the executor's CompletionQueue after each
    ``lcx.progress()``; watched completion objects (Synchronizer /
    CounterCompletion / custom ``signal`` overloads) resolve promise
    tasks the same way.
    """

    def __init__(self, device: Optional[lcx.Device] = None,
                 pool: Optional[lcx.PacketPool] = None,
                 graph: Optional[TaskGraph] = None, *,
                 runtime: Optional[lcx.Runtime] = None,
                 endpoint: Optional[lcx.Endpoint] = None,
                 progress_every: int = 8,
                 adaptive_progress: bool = True,
                 max_inflight: Optional[int] = None,
                 cq: Optional[lcx.CompletionQueue] = None,
                 fail_fast: bool = True,
                 max_task_retries: int = 0,
                 task_retry_backoff: int = 1,
                 name: str = "amt") -> None:
        self.name = name
        # Graceful degradation: with fail_fast=False a task exception is
        # recorded in ``task_status`` and the task is retried with
        # exponential backoff up to ``max_task_retries`` times, then
        # dead-lettered (its dependents cascade-fail) — the loop keeps
        # running instead of tearing down.
        self.fail_fast = fail_fast
        self.max_task_retries = max_task_retries
        self.task_retry_backoff = max(1, task_retry_backoff)
        self.dead_letter: List[Task] = []
        self.task_status: Dict[int, TaskStatus] = {}
        self._deferred: List[Tuple[int, int, Task]] = []  # (cycle, tie, task)
        # Resource injection (library-interop pattern): an executor given
        # an explicit runtime / endpoint / device keeps all its traffic on
        # those resources; with none it shares the global default runtime
        # (lazily created) so independently constructed executors can
        # still exchange active messages.
        self.endpoint = endpoint
        if device is None and endpoint is not None:
            device = endpoint.device
        if runtime is None:
            if endpoint is not None and endpoint.runtime is not None:
                runtime = endpoint.runtime
            elif device is not None and device.runtime is not None:
                runtime = device.runtime
        self._runtime = runtime
        if device is None and runtime is not None:
            device = runtime.default_device
        self._device = device if device is not None else lcx.Device()
        self.pool = pool
        self.graph = graph or TaskGraph()
        self.cq = cq if cq is not None else lcx.CompletionQueue()
        self.progress_every = max(1, progress_every)
        # Adaptive interval: doubles (up to 16x) each time a progress
        # call retires nothing, snaps back to ``progress_every`` as soon
        # as one retires something — idle polling backs off, busy phases
        # keep the configured cadence.
        self.adaptive_progress = adaptive_progress
        self._progress_interval = self.progress_every
        self._max_interval = self.progress_every * 16
        if max_inflight is None:
            if pool is not None:
                max_inflight = pool.get_attr_npackets()
            else:
                max_inflight = self.device.get_attr_max_inflight()
        self.max_inflight = max_inflight
        self.stats: Dict[str, int] = {
            "tasks_run": 0, "tasks_resumed": 0, "progress_calls": 0,
            "events_retired": 0, "backpressure_stalls": 0,
            "backpressure_deferrals": 0, "progress_backoffs": 0,
            "watch_fires": 0, "cycles": 0, "tasks_failed": 0,
            "task_retries": 0, "tasks_redispatched": 0,
        }
        self._heap: List[Tuple[int, int, Task]] = []
        self._tie = itertools.count()
        self._posted_since_progress = 0
        # (comp, k, promise) triples checked after each progress call
        self._watches: List[Tuple[Any, Callable[[Any], Any], Task]] = []
        self._activity = 0

    @property
    def runtime(self) -> lcx.Runtime:
        """The runtime this executor posts/progresses against (injected,
        else the global default)."""
        return self._runtime if self._runtime is not None else lcx.runtime()

    @property
    def device(self) -> lcx.Device:
        """The executor's posting device, following the failover
        forwarding chain: after ``runtime.failover(dev)`` the executor
        transparently posts on the survivor."""
        dev = self._device
        if dev.migrated_to is not None:
            dev = dev.resolve_migrated()
            self._device = dev
        return dev

    # -- submission -----------------------------------------------------------
    def spawn(self, fn: Callable[..., Any], *,
              deps: Tuple[Task, ...] = (), priority: int = 0,
              name: Optional[str] = None) -> Task:
        task = self.graph.add(fn, deps=deps, priority=priority, name=name)
        if task.n_waiting == 0:
            task.state = TaskState.READY
            self._push(task)
        self._activity += 1
        return task

    def submit(self, task: Task) -> Task:
        self.graph.add_task(task)
        if task.n_waiting == 0 and task.fn is not None:
            task.state = TaskState.READY
            self._push(task)
        self._activity += 1
        return task

    def promise(self, name: str = "promise") -> Task:
        """A task with no body, resolved externally (reply arrival,
        watched completion object, ...)."""
        task = self.graph.add(None, name=name)
        task.state = TaskState.BLOCKED
        return task

    def resolve_promise(self, task: Task, value: Any = None) -> None:
        self._retire(task, value)

    def watch(self, comp: Any,
              k: Optional[Callable[[Any], Any]] = None,
              name: str = "watch") -> Task:
        """Resolve a promise when ``comp.ready()`` becomes true (checked
        after every progress call).  ``k(comp)`` supplies the value."""
        promise = self.promise(name=name)
        self._watches.append((comp, k or (lambda c: c), promise))
        return promise

    # -- worker loop -----------------------------------------------------------
    def run(self, max_cycles: int = 100000) -> Dict[str, int]:
        """Drain the graph: execute ready tasks, interleave progress,
        retire completions.  Raises on deadlock (blocked tasks that no
        amount of progress can unblock)."""
        for t in self.graph.newly_ready():
            self._push(t)
        for _ in range(max_cycles):
            self.stats["cycles"] += 1
            before = self._activity
            self._release_deferred()
            while self._heap:
                deferred = False
                # Per-device backpressure: gate admission on the POSTING
                # device's pending depth (its packet pool), not the
                # runtime-wide ledger — a busy neighbour device must not
                # stall this executor's admission (docs/resources.md).
                while self.runtime.pending_for(self.device) \
                        >= self.max_inflight:
                    self.stats["backpressure_stalls"] += 1
                    pending_before = self.runtime.pending_for(self.device)
                    self._progress_and_retire()
                    if self.runtime.pending_for(self.device) >= pending_before:
                        # progress could not shrink the ledger — admitting
                        # more work would only deepen it; defer until the
                        # outer flush (or an external drain) frees packets
                        self.stats["backpressure_deferrals"] += 1
                        deferred = True
                        break
                if deferred:
                    break
                task = self._pop()
                if task is None:
                    break
                self._execute(task)
                if self._posted_since_progress >= self._progress_interval:
                    self._progress_and_retire()
            # Flush communication even when no task is runnable — an
            # arriving message may spawn work (active-message handlers).
            self._progress_and_retire()
            if not self.graph.unfinished():
                break
            if self._activity == before:
                if self._deferred or self.runtime.has_inflight():
                    # Not a deadlock: backed-off task retries and/or comm
                    # retries/timeouts are still pending — keep driving
                    # progress so their tick deadlines can elapse.
                    continue
                stuck = [t for t in self.graph.tasks.values()
                         if t.state in (TaskState.PENDING, TaskState.READY,
                                        TaskState.BLOCKED)]
                raise RuntimeError(
                    f"executor {self.name!r} deadlocked with "
                    f"{self.graph.unfinished()} unfinished tasks: "
                    f"{stuck[:8]}")
        else:
            raise RuntimeError(f"executor {self.name!r}: max_cycles "
                               "exceeded")
        return dict(self.stats)

    # -- internals -------------------------------------------------------------
    def _note_post(self) -> None:
        self._posted_since_progress += 1

    def _push(self, task: Task) -> None:
        heapq.heappush(self._heap, (-task.priority, next(self._tie), task))

    def _pop(self) -> Optional[Task]:
        while self._heap:
            _, _, task = heapq.heappop(self._heap)
            if task.state is TaskState.READY:
                return task
        return None

    def _execute(self, task: Task) -> None:
        task.state = TaskState.RUNNING
        ctx = TaskContext(self, task)
        try:
            out = task.fn(ctx)
        except BaseException as e:
            if self.fail_fast or not isinstance(e, Exception):
                self.graph.fail(task, e)
                raise
            self._handle_failure(task, e)
            return
        self.stats["tasks_run"] += 1
        self._activity += 1
        if out is PENDING:
            task.state = TaskState.BLOCKED
        else:
            self._retire(task, out)

    # -- graceful degradation ---------------------------------------------------
    def status_of(self, task: Task) -> TaskStatus:
        st = self.task_status.get(task.tid)
        if st is None:
            st = self.task_status[task.tid] = TaskStatus(task)
        return st

    def _handle_failure(self, task: Task, error: Exception) -> None:
        st = self.status_of(task)
        st.attempts += 1
        st.error = error
        self._activity += 1
        if st.attempts <= self.max_task_retries:
            st.state = "retrying"
            self.stats["task_retries"] += 1
            delay = self.task_retry_backoff * (1 << (st.attempts - 1))
            task.state = TaskState.PENDING
            heapq.heappush(self._deferred,
                           (self.stats["cycles"] + delay, next(self._tie),
                            task))
            return
        st.state = "failed"
        self.dead_letter.append(task)
        self._fail_task(task, error)

    def _fail_task(self, task: Task, error: BaseException) -> None:
        """Settle ``task`` as FAILED and cascade to dependents that can
        now never run (their error records why)."""
        if task.state in (TaskState.DONE, TaskState.FAILED):
            return
        self.graph.fail(task, error)
        self.stats["tasks_failed"] += 1
        self._activity += 1
        for dep in task.dependents:
            if dep.state in (TaskState.DONE, TaskState.FAILED):
                continue
            st = self.status_of(dep)
            st.state = "cascade"
            cascade = DependencyError(
                f"dependency {task.name!r} failed: {error!r}")
            st.error = cascade
            self._fail_task(dep, cascade)

    def _release_deferred(self) -> None:
        while self._deferred and self._deferred[0][0] <= self.stats["cycles"]:
            _, _, task = heapq.heappop(self._deferred)
            if task.state is TaskState.PENDING:
                task.state = TaskState.READY
                self._push(task)
                self._activity += 1

    def _retire(self, task: Task, result: Any) -> None:
        task.result = result
        for k in task.continuations:
            k(result)
        for ready in self.graph.retire(task):
            ready.state = TaskState.READY
            self._push(ready)
        self._activity += 1

    def _progress_and_retire(self) -> int:
        op = lcx.progress_x().runtime(self._runtime)
        if self.pool is not None:
            op = op.pool(self.pool)
        op()
        self.stats["progress_calls"] += 1
        self._posted_since_progress = 0
        # Batched retirement: ONE completion-queue drain per progress
        # call.  Events are first sorted into their suspended tasks; the
        # tasks whose event count is met resume in a single second pass
        # (resumptions may spawn/post, so they must not interleave with
        # the drain itself).
        events = self.cq.pop_all()
        n = len(events)
        self.stats["events_retired"] += n
        resumable: List[Task] = []
        redispatch: List[Task] = []
        for ev in events:
            task = ev.context
            if not isinstance(task, Task):
                continue  # foreign traffic on a shared queue
            if ev.migrated and ev.status is lcx.ErrorCode.RETRY \
                    and task.state is TaskState.BLOCKED:
                # Device failover could not replay this op on the
                # survivor (axis mismatch / replay disabled): re-dispatch
                # the suspended task so it re-posts on the migrated
                # device — a healthy task, not a dead-letter.
                if task not in redispatch:
                    redispatch.append(task)
                continue
            susp = task._suspension
            if susp is None or len(susp["events"]) >= susp["need"]:
                continue  # not suspended / already satisfied this batch
            susp["events"].append(ev)
            if len(susp["events"]) == susp["need"]:
                resumable.append(task)
        for task in redispatch:
            task._suspension = None
            task.state = TaskState.READY
            self._push(task)
            self.stats["tasks_redispatched"] += 1
            self._activity += 1
        for task in resumable:
            susp = task._suspension
            task._suspension = None
            k = susp["k"]
            evs = susp["events"]
            value = None
            if k is not None:
                value = k(evs[0]) if susp["need"] == 1 else k(evs)
            self.stats["tasks_resumed"] += 1
            self._retire(task, value)
        # Resolve watched completion objects (threshold counters etc.).
        still = []
        for comp, k, promise in self._watches:
            if getattr(comp, "ready", lambda: False)():
                self.stats["watch_fires"] += 1
                n += 1
                self.resolve_promise(promise, k(comp))
            else:
                still.append((comp, k, promise))
        self._watches = still
        # Adaptive back-off: a progress call that retires nothing widens
        # the posting interval; any retirement snaps it back.
        if self.adaptive_progress:
            if n == 0:
                if self._progress_interval < self._max_interval:
                    self._progress_interval = min(
                        self._progress_interval * 2, self._max_interval)
                    self.stats["progress_backoffs"] += 1
            else:
                self._progress_interval = self.progress_every
        return n
