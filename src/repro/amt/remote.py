"""Remote task spawning over LCX active messages.

A task handler is registered *by name* on every rank (SPMD: the same
registration code runs everywhere, so the table is identical — the
trace-time analogue of LCI's remote-completion-handler registry).
:meth:`RemoteSpawner.spawn` posts an ``am_x`` carrying the argument
payload toward the peer selected by ``perm``; at the destination the
message's :class:`~repro.core.resources.FunctionHandler` remote
completion fires during ``progress()`` and enqueues an *execution task*
on the destination executor.  If a reply is requested, that execution
task posts a second active message back along the inverse permutation,
resolving the promise the spawner returned.

Because ranks run in lockstep, reply-correlation ids advance
identically on every rank; the id in the (locally traced) event context
therefore names the same logical spawn on sender and receiver.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

import repro.core as lcx

from .executor import Executor
from .task import Task

_HANDLERS: Dict[str, Callable[[Any], Any]] = {}


@dataclasses.dataclass
class RemoteFailure:
    """Error result of a remote spawn — the reply-side analogue of a
    non-ok :class:`~repro.core.resources.ErrorCode`.

    Delivered as the promise's *value* (never raised from inside
    ``progress()``): an unregistered handler or a handler that raised on
    the peer resolves the spawner's promise with one of these instead of
    wedging it forever.
    """

    handler: str
    status: str            # "unknown_handler" | "handler_error"
    message: str = ""

    @property
    def ok(self) -> bool:
        return False


def register_task_handler(name: str, fn: Callable[[Any], Any]) -> str:
    """Register ``fn`` under ``name`` (must run on every rank)."""
    _HANDLERS[name] = fn
    return name


def task_handler(name: Optional[str] = None):
    """Decorator form of :func:`register_task_handler`."""

    def deco(fn: Callable[[Any], Any]) -> Callable[[Any], Any]:
        register_task_handler(name or fn.__name__, fn)
        return fn

    return deco


def clear_task_handlers() -> None:
    _HANDLERS.clear()


class RemoteSpawner:
    """Remote-spawn endpoint bound to one executor (one per rank)."""

    def __init__(self, executor: Executor,
                 device: Optional[lcx.Device] = None,
                 endpoint: Optional[lcx.Endpoint] = None) -> None:
        self.executor = executor
        self.endpoint = endpoint if endpoint is not None else executor.endpoint
        if device is None and endpoint is not None:
            device = endpoint.device
        self.device = device or executor.device
        self._fh = lcx.FunctionHandler(self._deliver)
        self._reply_fh = lcx.FunctionHandler(self._deliver_reply)
        self._reply_ids = itertools.count(1)
        self._pending_replies: Dict[int, Task] = {}
        self.stats: Dict[str, int] = {
            "unknown_handlers": 0, "handler_errors": 0,
            "orphan_replies": 0,
        }

    # -- sender side -----------------------------------------------------------
    def spawn(self, name: str, payload: Any, perm: lcx.Perm, *,
              reply: bool = True, priority: int = 0,
              tag: int = 0) -> Optional[Task]:
        """Spawn handler ``name`` on the peer(s) named by ``perm``,
        shipping ``payload``.  Returns a promise task that resolves with
        the peer's result (or None when ``reply=False``)."""
        if name not in _HANDLERS:
            raise KeyError(f"no task handler registered as {name!r}; "
                           f"known: {sorted(_HANDLERS)}")
        promise = None
        reply_id = 0
        if reply:
            reply_id = next(self._reply_ids)
            promise = self.executor.promise(name=f"reply:{name}:{reply_id}")
            self._pending_replies[reply_id] = promise
        lcx.am_x(payload).perm(perm).tag(tag).remote_comp(self._fh) \
            .runtime(self.executor._runtime).endpoint(self.endpoint) \
            .ctx({"handler": name, "reply_id": reply_id, "perm": perm,
                  "priority": priority}).device(self.device)()
        self.executor._note_post()
        return promise

    # -- receiver side (both run during lcx.progress) ---------------------------
    def _reply_error(self, ctx: Any, info: Dict[str, Any], status: str,
                     message: str) -> RemoteFailure:
        """Ship an error-status reply (dummy payload, the error rides in
        the trace-time context) so the spawner's promise resolves with a
        :class:`RemoteFailure` instead of hanging."""
        failure = RemoteFailure(handler=info["handler"], status=status,
                                message=message)
        if info["reply_id"]:
            lcx.am_x(jnp.zeros(())).perm(info["perm"].inverse()) \
                .remote_comp(self._reply_fh) \
                .runtime(self.executor._runtime).endpoint(self.endpoint) \
                .ctx({"reply_id": info["reply_id"], "status": status,
                      "error": message, "handler": info["handler"]}) \
                .device(self.device)()
            ctx.executor._note_post()
        return failure

    def _deliver(self, ev: lcx.Event) -> Task:
        info = ev.context

        def run_remote(ctx: Any, _payload: Any = ev.payload,
                       _info: Dict[str, Any] = info) -> Any:
            fn = _HANDLERS.get(_info["handler"])
            if fn is None:
                self.stats["unknown_handlers"] += 1
                return self._reply_error(
                    ctx, _info, "unknown_handler",
                    f"no task handler registered as {_info['handler']!r}")
            try:
                result = fn(_payload)
            except Exception as e:
                self.stats["handler_errors"] += 1
                return self._reply_error(ctx, _info, "handler_error",
                                         f"{type(e).__name__}: {e}")
            if _info["reply_id"]:
                lcx.am_x(result).perm(_info["perm"].inverse()) \
                    .remote_comp(self._reply_fh) \
                    .runtime(self.executor._runtime).endpoint(self.endpoint) \
                    .ctx({"reply_id": _info["reply_id"]}) \
                    .device(self.device)()
                ctx.executor._note_post()
            return result

        return self.executor.spawn(
            run_remote, priority=info.get("priority", 0),
            name=f"remote:{info['handler']}")

    def _deliver_reply(self, ev: lcx.Event) -> None:
        info = ev.context
        promise = self._pending_replies.pop(info["reply_id"], None)
        if promise is None:
            # duplicate / late reply (e.g. FaultyTransport duplication)
            self.stats["orphan_replies"] += 1
            return
        if info.get("status"):
            self.executor.resolve_promise(
                promise, RemoteFailure(handler=info.get("handler", "?"),
                                       status=info["status"],
                                       message=info.get("error", "")))
        else:
            self.executor.resolve_promise(promise, ev.payload)
