"""Tasks and task graphs for the AMT executor.

A :class:`Task` is a unit of work — a Python callable (usually closing
over traced JAX values) invoked once by an executor with a
``TaskContext``.  Tasks carry *dependencies* (tasks that must finish
first), a *priority* (higher runs earlier among ready tasks), and
*continuations* (callbacks fired with the task's result when it
retires).  A :class:`TaskGraph` owns a set of tasks and the dependency
bookkeeping the executor schedules from.

The graph is deliberately communication-agnostic: an edge says "B needs
A's result", nothing more.  When an edge is *physically* a message —
e.g. the inter-stage activation transfer of a pipeline — the sending
task posts an LCX operation and suspends; the executor resumes it from
the completion object (see ``executor.py``).
"""
from __future__ import annotations

import enum
import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional


class TaskState(enum.Enum):
    PENDING = "pending"      # waiting on dependencies
    READY = "ready"          # dependencies met, queued for execution
    RUNNING = "running"      # body executing
    BLOCKED = "blocked"      # suspended on a completion object
    DONE = "done"
    FAILED = "failed"


_TASK_IDS = itertools.count()


class Task:
    """A schedulable unit of work with dependencies and continuations."""

    __slots__ = ("tid", "fn", "name", "priority", "state", "result",
                 "error", "deps", "dependents", "n_waiting",
                 "continuations", "_graph", "_suspension")

    def __init__(self, fn: Optional[Callable[..., Any]], *,
                 name: Optional[str] = None, priority: int = 0,
                 deps: Iterable["Task"] = ()) -> None:
        self.tid = next(_TASK_IDS)
        self.fn = fn
        self.name = name or (getattr(fn, "__name__", None)
                             or f"task{self.tid}")
        self.priority = priority
        self.state = TaskState.PENDING
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.deps: List["Task"] = [d for d in deps if d is not None]
        self.dependents: List["Task"] = []
        self.n_waiting = 0
        self.continuations: List[Callable[[Any], Any]] = []
        self._graph: Optional["TaskGraph"] = None
        # set by TaskContext.suspend: {"k", "need", "events"}
        self._suspension: Optional[Dict[str, Any]] = None

    # -- chaining ------------------------------------------------------------
    def then(self, fn: Callable[[Any], Any], *,
             priority: Optional[int] = None,
             name: Optional[str] = None) -> "Task":
        """Chain a dependent task that runs ``fn(self.result)``."""
        if self._graph is None:
            raise RuntimeError(f"{self!r} is not in a TaskGraph; add it "
                               "before chaining")
        return self._graph.add(
            lambda ctx, _p=self: fn(_p.result),
            deps=(self,), name=name or f"{self.name}.then",
            priority=self.priority if priority is None else priority)

    def on_done(self, fn: Callable[[Any], Any]) -> "Task":
        """Register a lightweight continuation (no new task): ``fn`` is
        invoked with the result at retirement."""
        self.continuations.append(fn)
        return self

    @property
    def done(self) -> bool:
        return self.state is TaskState.DONE

    def __repr__(self) -> str:
        return (f"Task<{self.name}#{self.tid} {self.state.value} "
                f"prio={self.priority}>")


class TaskGraph:
    """Dependency DAG of tasks plus the ready-set bookkeeping."""

    def __init__(self) -> None:
        self.tasks: Dict[int, Task] = {}
        self._n_unfinished = 0

    # -- construction --------------------------------------------------------
    def add(self, fn: Optional[Callable[..., Any]] = None, *,
            deps: Iterable[Task] = (), priority: int = 0,
            name: Optional[str] = None) -> Task:
        task = Task(fn, name=name, priority=priority, deps=deps)
        return self.add_task(task)

    def add_task(self, task: Task) -> Task:
        if task.tid in self.tasks:
            return task
        task._graph = self
        self.tasks[task.tid] = task
        self._n_unfinished += 1
        task.n_waiting = 0
        for dep in task.deps:
            if dep.tid not in self.tasks:
                raise ValueError(f"dependency {dep!r} of {task!r} is not "
                                 "in this graph")
            if dep.state not in (TaskState.DONE, TaskState.FAILED):
                dep.dependents.append(task)
                task.n_waiting += 1
        return task

    # -- scheduling queries --------------------------------------------------
    def newly_ready(self) -> List[Task]:
        """PENDING tasks whose dependencies are all met; marks them READY."""
        out = []
        for t in self.tasks.values():
            if t.state is TaskState.PENDING and t.n_waiting == 0 \
                    and t.fn is not None:
                t.state = TaskState.READY
                out.append(t)
        return out

    def unfinished(self) -> int:
        return self._n_unfinished

    def retire(self, task: Task) -> List[Task]:
        """Mark DONE; return dependents that just became dependency-free."""
        if task.state is TaskState.DONE:
            return []
        task.state = TaskState.DONE
        self._n_unfinished -= 1
        unblocked = []
        for d in task.dependents:
            d.n_waiting -= 1
            if d.n_waiting == 0 and d.state is TaskState.PENDING:
                unblocked.append(d)
        return unblocked

    def fail(self, task: Task, error: BaseException) -> None:
        if task.state in (TaskState.FAILED, TaskState.DONE):
            return  # already settled (e.g. cascade hit it twice)
        task.state = TaskState.FAILED
        task.error = error
        self._n_unfinished -= 1

    def validate_acyclic(self) -> None:
        """Kahn's algorithm over the current graph; raises on a cycle."""
        indeg = {t.tid: sum(1 for d in t.deps
                            if d.state not in (TaskState.DONE,
                                               TaskState.FAILED))
                 for t in self.tasks.values()}
        frontier = [t for t in self.tasks.values() if indeg[t.tid] == 0]
        seen = 0
        while frontier:
            t = frontier.pop()
            seen += 1
            for d in t.dependents:
                indeg[d.tid] -= 1
                if indeg[d.tid] == 0:
                    frontier.append(d)
        if seen != len(self.tasks):
            cyclic = [t.name for t in self.tasks.values()
                      if indeg[t.tid] > 0]
            raise ValueError(f"task graph has a cycle through {cyclic}")

    def __len__(self) -> int:
        return len(self.tasks)
