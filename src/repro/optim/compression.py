"""Gradient compression: int8 quantization with error feedback.

Two integration points:

- :func:`compressed_psum` — an LCX-flavored DP all-reduce: quantize the
  local gradient to int8 (per-tensor scale), sum int32 across the axis
  (4x fewer bytes on the wire than f32, 2x fewer than bf16), dequantize.
  Used in explicit shard_map DP regions (cross-pod reduction stage).
- :class:`CompressedAccumulator` — int8 + error-feedback gradient
  *accumulator* for microbatched training: the accumulation buffer costs
  1 byte/param instead of 4, and the quantization error is carried to
  the next microbatch so it cancels instead of biasing (Seide et al.
  error feedback).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any
INT8_MAX = 127.0


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (q int8, scale f32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / INT8_MAX
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype: Any = jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis: str,
                    err: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """All-reduce ``x`` over ``axis`` in int8 (+ f32 scale exchange).

    Returns (mean-reduced value, new error-feedback residual).  Must run
    under shard_map/vmap with ``axis`` bound.
    """
    from repro.compat import axis_size
    n = axis_size(axis)
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    # shared scale: max(|x|) across ranks so the int32 sum cannot overflow
    amax = lax.pmax(jnp.max(jnp.abs(xf)), axis)
    scale = jnp.maximum(amax / INT8_MAX, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX)
    new_err = xf - q * scale                       # local residual
    total = lax.psum(q.astype(jnp.int32), axis)
    out = (total.astype(jnp.float32) * scale / n).astype(x.dtype)
    return out, new_err.astype(jnp.float32)


class CompressedAccumulator:
    """int8 + error-feedback microbatch gradient accumulator (functional:
    all state returned, safe under jit)."""

    @staticmethod
    def init(params: PyTree) -> PyTree:
        return jax.tree.map(
            lambda p: {"q": jnp.zeros(p.shape, jnp.int8),
                       "scale": jnp.zeros((), jnp.float32),
                       "err": jnp.zeros(p.shape, jnp.float32)}, params)

    @staticmethod
    def add(acc: PyTree, grads: PyTree) -> PyTree:
        def one(a, g):
            cur = a["q"].astype(jnp.float32) * a["scale"] + a["err"]
            tot = cur + g.astype(jnp.float32)
            q, scale = compress_int8(tot)
            err = tot - q.astype(jnp.float32) * scale
            return {"q": q, "scale": scale, "err": err}
        return jax.tree.map(one, acc, grads,
                            is_leaf=lambda t: isinstance(t, dict)
                            and "q" in t)

    @staticmethod
    def value(acc: PyTree, count: int) -> PyTree:
        return jax.tree.map(
            lambda a: (a["q"].astype(jnp.float32) * a["scale"] + a["err"])
            / count,
            acc, is_leaf=lambda t: isinstance(t, dict) and "q" in t)
