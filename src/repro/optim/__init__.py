from .adamw import (AdamWState, adamw_init, adamw_update, cosine_schedule,
                    global_norm, clip_by_global_norm)
from .compression import (compress_int8, decompress_int8, compressed_psum,
                          CompressedAccumulator)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm", "compress_int8",
           "decompress_int8", "compressed_psum", "CompressedAccumulator"]
