"""Sharded AdamW + cosine schedule + global-norm clipping.

Moment dtype is configurable (``cfg.opt_dtype``): fp32 for fidelity,
bf16 or int8-blockwise (via `repro.optim.compression`) to fit very large
models — the moments inherit the parameters' shardings, so optimizer
state is always fully sharded (ZeRO-ish by construction: params are
FSDP-sharded by the rules in `repro.parallel.sharding`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: PyTree
    v: PyTree

    def tree_flatten(self):
        return (self.step, self.m, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    AdamWState, AdamWState.tree_flatten, AdamWState.tree_unflatten)


def adamw_init(params: PyTree, dtype: Any = jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_update(params: PyTree, grads: PyTree, state: AdamWState, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 ) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay \
            * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    newp = jax.tree.unflatten(tdef, [o[0] for o in out])
    newm = jax.tree.unflatten(tdef, [o[1] for o in out])
    newv = jax.tree.unflatten(tdef, [o[2] for o in out])
    return newp, AdamWState(step=step, m=newm, v=newv)
