"""End-to-end training driver: a ~smoke-size qwen2-style LM trained for
a few hundred steps with the full production substrate — sharded params
(if >1 device), microbatched gradient accumulation, checkpointing, an
injected node failure (recovered from the last checkpoint), and
straggler monitoring.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime import FailureInjector, TrainConfig, Trainer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--d-model", type=int, default=128)
    p.add_argument("--layers", type=int, default=4)
    args = p.parse_args()

    # ~100M-class config scaled to CPU budget: same family as qwen2
    cfg = ModelConfig(
        name="qwen2-mini", family="dense",
        n_layers=args.layers, d_model=args.d_model, n_heads=8,
        n_kv_heads=2, d_ff=4 * args.d_model, vocab=2048,
        qkv_bias=True, tie_embeddings=True,
        dtype=jnp.float32, param_dtype=jnp.float32, remat="none",
        q_block=64,
    )
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainConfig(
            lr=1e-3, warmup=30, total_steps=args.steps,
            seq_len=128, global_batch=16, grad_accum=2,
            ckpt_dir=ckpt_dir, ckpt_every=50, log_every=20,
        )
        injector = FailureInjector(fail_at=[args.steps // 2])
        trainer = Trainer(cfg, tcfg, mesh=make_host_mesh(),
                          failure_injector=injector)
        out = trainer.run(args.steps)
        print(f"\nfinal step {out['final_step']}, "
              f"{out['failures']} failure(s) recovered")
        first = trainer.metrics_log[0]
        last = trainer.metrics_log[-1]
        print(f"loss: {first['loss']:.3f} -> {last['loss']:.3f}")
        for m in trainer.metrics_log:
            print(f"  step {m['step']:4d}  loss={m['loss']:.4f}  "
                  f"lr={m['lr']:.2e}  {m['dt']*1e3:6.0f}ms  "
                  f"{m['straggler']}")
        assert last["loss"] < first["loss"], "training did not learn"
        print("train_lm OK")


if __name__ == "__main__":
    main()
