"""LCX quickstart — the paper's interface in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py

Walks the core concepts on a 4-rank emulated axis: objectized flexible
functions (Listing 1.1), resources × operations orthogonality, the three
completion object types, matching engines, explicit progress, and a
ring all-reduce built from LCX puts.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

import repro.core as lcx


def per_rank(x):
    # Default resources are allocated by the runtime (opt-out available).
    lcx.init()
    dev = lcx.Device(axis="x")                  # the "NIC" onto the mesh

    # --- Listing 1.1: objectized flexible functions -------------------
    # D d = foo_x(a1).c(c1)();  ->  chainable setters, any order, reusable
    sync = lcx.Synchronizer(threshold=1)
    op = lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(sync).device(dev)
    op()                                        # post (asynchronous!)
    lcx.progress()                              # explicit progress
    (ev,) = sync.wait()
    neighbour = ev.payload                      # RDMA-write-with-signal

    # --- any op x any completion object --------------------------------
    cq = lcx.CompletionQueue()
    fh = lcx.FunctionHandler(lambda e: e.payload * 2)
    lcx.am_x(x).perm(lcx.Perm.shift(2)).remote_comp(cq).device(dev)()
    lcx.am_x(x).perm(lcx.Perm.shift(1)).remote_comp(fh).device(dev)()
    lcx.progress()
    from_two_away = cq.pop().payload
    doubled = fh.results[0]

    # --- matched send/recv through a matching engine -------------------
    eng = lcx.MatchingEngine(kind="map", policy="rank_tag")
    s2 = lcx.Synchronizer(threshold=2)
    lcx.send_x(x * 10).perm(lcx.Perm.shift(1)).tag(7).comp(s2) \
        .matching_engine(eng).device(dev)()
    lcx.recv_x(x).perm(lcx.Perm.shift(1)).tag(7).comp(s2) \
        .matching_engine(eng).device(dev)()
    lcx.progress()
    matched = [e.payload for e in s2.wait() if e.payload is not None][0]

    # --- a collective built from LCX p2p -------------------------------
    total = lcx.all_reduce(x, device=dev, backend="ring")

    return neighbour, from_two_away, doubled, matched, total


def main():
    xs = jnp.arange(4.0)
    nb, two, dbl, matched, total = jax.vmap(per_rank, axis_name="x")(xs)
    print("rank values:        ", xs)
    print("left neighbour:     ", nb)
    print("two ranks away:     ", two)
    print("am handler (2x):    ", dbl)
    print("matched send (10x): ", matched)
    print("ring all-reduce:    ", total)
    assert (total == xs.sum()).all()
    print("quickstart OK")


if __name__ == "__main__":
    main()
