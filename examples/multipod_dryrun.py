"""Multi-pod launch example: lower+compile one architecture for the
production meshes (single pod 16x16 and two pods 2x16x16) and print the
roofline breakdown — the exact flow a cluster launcher runs before
committing 512 chips.

Run:  PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# MUST precede any jax import (jax locks the device count on first init)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import run_cell  # noqa: E402


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-0.5b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    print(f"== {arch} {shape} : single pod (16x16 = 256 chips) ==")
    run_cell(arch, shape, multi_pod=False)
    print(f"== {arch} {shape} : two pods (2x16x16 = 512 chips) ==")
    run_cell(arch, shape, multi_pod=True)
    print("multipod_dryrun OK")


if __name__ == "__main__":
    main()
