"""Serving example: continuous batching over a hybrid (Mamba+attention)
model — prefill into slots, per-tick batched decode, slot recycling,
and a greedy-consistency check against the full forward pass.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import apply_model, init_model
from repro.serving import Request, ServeConfig, ServingEngine


def main():
    cfg = ModelConfig(
        name="jamba-mini", family="hybrid",
        n_layers=8, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
        vocab=1024, attn_layer_period=4, attn_layer_offset=1,
        n_experts=4, n_experts_per_tok=2, moe_d_ff=128,
        expert_layer_period=2, expert_layer_offset=1,
        moe_backend="sort", capacity_factor=4.0,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        dtype=jnp.float32, param_dtype=jnp.float32, q_block=32,
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(
        n_slots=4, max_seq=128, max_new_tokens=16))

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(10):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32)))
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0

    tok = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s)  stats={eng.stats}")

    # consistency: engine output == token-by-token full forward (greedy)
    r = done[0]
    toks = list(r.prompt)
    for _ in range(len(r.output)):
        lg, _ = apply_model(cfg, params, jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert toks[len(r.prompt):] == r.output, "engine diverged from model"
    print("greedy consistency OK")
    for r in done[:3]:
        print(f"  rid={r.rid}: {list(r.prompt)[:5]}... -> {r.output[:8]}")
    print("serve_lm OK")


if __name__ == "__main__":
    main()
