"""Serving engine: continuous batching, greedy agreement with the full
forward, slot recycling, temperature sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import apply_model, init_model
from repro.serving import Request, ServeConfig, ServingEngine

F32 = dict(dtype=jnp.float32, param_dtype=jnp.float32, q_block=8)


def make(cfg):
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    return params


@pytest.fixture(scope="module")
def dense_setup():
    cfg = ModelConfig(name="d", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=211, **F32)
    return cfg, make(cfg)


def test_continuous_batching_drains(dense_setup):
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params, ServeConfig(n_slots=3, max_seq=64,
                                                 max_new_tokens=6))
    for i in range(7):
        eng.submit(Request(rid=i,
                           prompt=np.arange(4 + i % 3, dtype=np.int32)))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(len(r.output) == 6 for r in done)
    assert eng.stats["prefills"] == 7
    # slots were recycled: more requests than slots
    assert eng.stats["ticks"] >= 2


def test_greedy_matches_full_forward(dense_setup):
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params, ServeConfig(n_slots=2, max_seq=64,
                                                 max_new_tokens=5))
    eng.submit(Request(rid=0, prompt=np.arange(7, dtype=np.int32)))
    done = eng.run_until_drained()
    r = done[0]
    toks = list(r.prompt)
    for _ in range(len(r.output)):
        lg, _ = apply_model(cfg, params,
                            jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert toks[len(r.prompt):] == r.output


def test_hybrid_serving_greedy():
    cfg = ModelConfig(name="h", family="hybrid", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                      attn_layer_period=4, attn_layer_offset=1,
                      ssm_state=16, ssm_head_dim=16, ssm_chunk=8, **F32)
    params = make(cfg)
    eng = ServingEngine(cfg, params, ServeConfig(n_slots=2, max_seq=64,
                                                 max_new_tokens=4))
    eng.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32)))
    done = eng.run_until_drained()
    r = done[0]
    toks = list(r.prompt)
    for _ in range(len(r.output)):
        lg, _ = apply_model(cfg, params,
                            jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert toks[len(r.prompt):] == r.output


def test_eos_terminates(dense_setup):
    cfg, params = dense_setup
    # find the greedy first token and use it as EOS: request stops at 1
    eng0 = ServingEngine(cfg, params, ServeConfig(n_slots=1, max_seq=64,
                                                  max_new_tokens=3))
    eng0.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32)))
    first = eng0.run_until_drained()[0].output[0]

    eng = ServingEngine(cfg, params, ServeConfig(n_slots=1, max_seq=64,
                                                 max_new_tokens=50,
                                                 eos_token=first))
    eng.submit(Request(rid=1, prompt=np.arange(5, dtype=np.int32)))
    done = eng.run_until_drained()
    assert done[0].output == [first]


def test_per_request_max_new(dense_setup):
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params, ServeConfig(n_slots=2, max_seq=64,
                                                 max_new_tokens=10))
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2))
    done = eng.run_until_drained()
    assert len(done[0].output) == 2


def test_oversized_prompt_rejected(dense_setup):
    cfg, params = dense_setup
    eng = ServingEngine(cfg, params, ServeConfig(n_slots=1, max_seq=16))
    eng.submit(Request(rid=0, prompt=np.arange(20, dtype=np.int32)))
    done = eng.run_until_drained()
    assert done[0].done and done[0].output == []


def test_temperature_sampling_varies(dense_setup):
    cfg, params = dense_setup
    outs = set()
    for seed in range(3):
        eng = ServingEngine(cfg, params, ServeConfig(
            n_slots=1, max_seq=64, max_new_tokens=8, temperature=1.5,
            seed=seed))
        eng.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32)))
        outs.add(tuple(eng.run_until_drained()[0].output))
    assert len(outs) > 1
