"""GPipe pipeline built on LCX send/recv (vmap-emulated pipe axis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx
from repro.parallel.pipeline import gpipe

N_STAGES = 4


def test_gpipe_matches_sequential():
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (N_STAGES, 8, 8)) / jnp.sqrt(8.0)
    bs = jax.random.normal(jax.random.fold_in(key, 1), (N_STAGES, 8)) * 0.1
    micro = jax.random.normal(jax.random.fold_in(key, 2), (6, 3, 8))

    def stage_fn(params, x):
        w, b = params
        return jnp.tanh(x @ w + b)

    def per_rank(w, b):
        lcx.init()
        return gpipe(stage_fn, (w, b), micro, axis="pipe")

    out = jax.vmap(per_rank, axis_name="pipe")(ws, bs)

    # sequential reference
    ref = micro
    for i in range(N_STAGES):
        ref = jnp.tanh(ref @ ws[i] + bs[i])

    for r in range(N_STAGES):   # broadcast to all ranks
        np.testing.assert_allclose(np.asarray(out[r]), np.asarray(ref),
                                   atol=1e-5)


def test_gpipe_native_backend_matches_lcx():
    ws = jax.random.normal(jax.random.PRNGKey(1), (N_STAGES, 4, 4)) * 0.3
    micro = jax.random.normal(jax.random.PRNGKey(2), (5, 2, 4))

    def stage_fn(w, x):
        return x @ w

    def per_rank(use_lcx):
        def body(w):
            lcx.init()
            return gpipe(stage_fn, w, micro, axis="pipe", use_lcx=use_lcx)
        return jax.vmap(body, axis_name="pipe")(ws)

    np.testing.assert_allclose(np.asarray(per_rank(True)),
                               np.asarray(per_rank(False)), atol=1e-6)
