"""HLO walker + roofline term derivation against analytically-known
programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_walk import parse_computations, walk
from repro.analysis.roofline import model_flops, active_params


def test_walk_plain_matmul():
    @jax.jit
    def mm(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    hlo = mm.lower(a, a).compile().as_text()
    t = walk(hlo)
    assert t.flops == pytest.approx(2 * 512 ** 3, rel=0.01)


def test_walk_scan_multiplies_trip_count():
    @jax.jit
    def scanned(a, ws):
        def body(x, w):
            return x @ w, None
        out, _ = jax.lax.scan(body, a, ws)
        return out

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    hlo = scanned.lower(a, ws).compile().as_text()
    t = walk(hlo)
    assert t.flops == pytest.approx(7 * 2 * 256 ** 3, rel=0.02)


def test_walk_nested_scan():
    @jax.jit
    def nested(a, ws):
        def outer(x, w):
            def inner(y, _):
                return y @ w, None
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None
        out, _ = jax.lax.scan(outer, a, ws)
        return out

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    hlo = nested.lower(a, ws).compile().as_text()
    t = walk(hlo)
    assert t.flops == pytest.approx(5 * 3 * 2 * 128 ** 3, rel=0.05)


def test_collective_parse_synthetic():
    hlo = """
HloModule m

ENTRY %main (p0: f32[1024,1024]) -> f32[1024,1024] {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %ar = f32[1024,1024]{1,0} all-reduce(%p0), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %ag = f32[1024,1024]{1,0} all-gather(%ar), replica_groups=[32,8]<=[256], dimensions={0}
}
"""
    t = walk(hlo)
    nbytes = 1024 * 1024 * 4
    expect = 2 * nbytes * 15 / 16 + nbytes * 7 / 8
    assert t.coll_wire == pytest.approx(expect, rel=0.01)
    assert t.coll_by_kind["all-reduce"] == pytest.approx(
        2 * nbytes * 15 / 16)


def test_collective_brace_groups():
    hlo = """
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    t = walk(hlo)
    assert t.coll_wire == pytest.approx(2 * 64 * 4 * 3 / 4)


def test_model_flops_conventions():
    from repro.configs.base import ModelConfig
    from repro.models import abstract_init
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=100)
    proto, _ = abstract_init(cfg)
    total, act = active_params(cfg, proto)
    assert act < total           # embeddings excluded
    mf_train = model_flops(cfg, proto, "train", 128, 4)
    mf_dec = model_flops(cfg, proto, "decode", 128, 4)
    assert mf_train == pytest.approx(6 * act * 128 * 4)
    assert mf_dec == pytest.approx(2 * act * 4)


def test_moe_active_params_scaled():
    from repro.configs.base import ModelConfig
    from repro.models import abstract_init
    cfg = ModelConfig(family="moe", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab=100, n_experts=8,
                      n_experts_per_tok=2, moe_d_ff=64,
                      moe_backend="sort")
    proto, _ = abstract_init(cfg)
    total, act = active_params(cfg, proto)
    # routed experts contribute k/E of their params
    expert_params = 3 * 8 * 64 * 64 * 2   # gate/up/down x E x d x f x 2 layers
    assert act < total - expert_params * 0.5
