"""AMT executor on LCX completion objects: task graphs, completion-driven
retirement, remote spawning, GPipe-as-TaskGraph, and completion-object
behaviour under load (multi-rank comm tests use the vmap-emulated axis,
like test_core_ops)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx
from repro.amt import (Executor, RemoteSpawner, Task, TaskGraph, TaskState,
                       register_task_handler)

N = 4


def ranked(fn, n=N):
    xs = jnp.arange(float(n))
    return jax.vmap(fn, axis_name="x")(xs)


# ---------------------------------------------------------------------------
# Task graph semantics (loopback device — no axis needed)
# ---------------------------------------------------------------------------
def test_diamond_executes_in_topological_order():
    lcx.init()
    ex = Executor()
    order = []

    a = ex.spawn(lambda ctx: order.append("a") or 1, name="a")
    b = ex.spawn(lambda ctx: order.append("b") or a.result + 10,
                 deps=(a,), name="b", priority=1)
    c = ex.spawn(lambda ctx: order.append("c") or a.result + 20,
                 deps=(a,), name="c")
    d = ex.spawn(lambda ctx: order.append("d") or b.result + c.result,
                 deps=(b, c), name="d")
    ex.run()

    assert order.index("a") == 0 and order.index("d") == 3
    # priority: b (prio 1) before c (prio 0)
    assert order == ["a", "b", "c", "d"]
    assert d.result == 32
    assert all(t.state is TaskState.DONE for t in (a, b, c, d))


def test_priorities_order_independent_tasks():
    lcx.init()
    ex = Executor()
    order = []
    for name, prio in (("low", -1), ("hi", 5), ("mid", 2)):
        ex.spawn(lambda ctx, n=name: order.append(n), priority=prio,
                 name=name)
    ex.run()
    assert order == ["hi", "mid", "low"]


def test_continuations_and_then_chaining():
    lcx.init()
    ex = Executor()
    seen = []
    a = ex.spawn(lambda ctx: 7, name="a")
    a.on_done(lambda r: seen.append(r))
    doubled = a.then(lambda r: r * 2)
    ex.run()
    assert seen == [7]
    assert doubled.result == 14


def test_cycle_detection():
    g = TaskGraph()
    a = g.add(lambda ctx: None, name="a")
    b = g.add(lambda ctx: None, deps=(a,), name="b")
    # manufacture a cycle a -> b -> a
    b.dependents.append(a)
    a.deps.append(b)
    a.n_waiting += 1
    with pytest.raises(ValueError):
        g.validate_acyclic()


def test_deadlock_detected():
    lcx.init()
    ex = Executor()
    ex.promise(name="never-resolved")
    with pytest.raises(RuntimeError, match="deadlock"):
        ex.run()


# ---------------------------------------------------------------------------
# Completion-driven retirement (no polling waits)
# ---------------------------------------------------------------------------
def test_comm_task_resumes_from_completion_queue_not_wait(monkeypatch):
    """A suspended comm task must retire via the executor's CQ drain;
    Synchronizer.wait (the polling path) must never run."""
    monkeypatch.setattr(
        lcx.Synchronizer, "wait",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("executor must not poll Synchronizer.wait")))

    def body(x):
        lcx.init()
        ex = Executor(device=lcx.Device(axis="x"), name="cq-test")
        got = {}

        def talker(ctx):
            ctx.put(x, lcx.Perm.shift(1))
            return ctx.suspend(lambda ev: ev.payload)

        t = ex.spawn(talker, name="talker")
        t.on_done(lambda r: got.__setitem__("v", r))
        stats = ex.run()
        assert stats["events_retired"] == 1
        assert stats["tasks_resumed"] == 1
        return got["v"]

    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_suspend_on_multiple_events():
    """One task waits on n_events=3 arrivals, combined at resumption."""

    def body(x):
        lcx.init()
        ex = Executor(device=lcx.Device(axis="x"))

        def talker(ctx):
            for i in range(3):
                ctx.put(x + i, lcx.Perm.shift(1), tag=i)
            return ctx.suspend(
                lambda evs: sum(e.payload for e in evs), n_events=3)

        t = ex.spawn(talker)
        ex.run()
        return t.result

    out = ranked(body)
    # neighbour value v: v + (v+1) + (v+2)
    v = np.array([3.0, 0.0, 1.0, 2.0])
    np.testing.assert_allclose(out, 3 * v + 3)


def test_progress_interleaved_with_execution():
    """progress_every batches posts: the executor drives progress between
    task executions, not one blocking progress at the end."""
    lcx.init()
    ex = Executor(progress_every=1)

    def maker(i):
        def fn(ctx):
            ctx.put(jnp.float32(i), None)   # loopback: self-delivery
            return ctx.suspend(lambda ev: float(ev.payload))
        return fn

    tasks = [ex.spawn(maker(i), name=f"p{i}") for i in range(5)]
    stats = ex.run()
    assert [t.result for t in tasks] == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert stats["progress_calls"] >= 5


# ---------------------------------------------------------------------------
# Backpressure (packet-pool aware admission)
# ---------------------------------------------------------------------------
def test_backpressure_stalls_admission():
    lcx.init()
    ex = Executor(max_inflight=2, progress_every=1000)

    def maker(i):
        def fn(ctx):
            ctx.put(jnp.float32(i), None)
            return ctx.suspend(lambda ev: float(ev.payload))
        return fn

    tasks = [ex.spawn(maker(i)) for i in range(6)]
    stats = ex.run()
    assert stats["backpressure_stalls"] > 0
    assert sorted(t.result for t in tasks) == [float(i) for i in range(6)]


def test_pool_sized_inflight_limit():
    lcx.init()
    pool = lcx.PacketPool(npackets=3)
    ex = Executor(pool=pool)
    assert ex.max_inflight == 3


def test_executor_stats_meaningful_under_batched_retirement():
    """Batched CQ drains must keep events_retired == events delivered
    and backpressure_stalls counting real admission stalls."""
    lcx.init()
    ex = Executor(max_inflight=2, progress_every=1000)
    n_tasks, n_puts = 5, 3

    def maker(i):
        def fn(ctx):
            for j in range(n_puts):
                ctx.put(jnp.float32(i * n_puts + j), None, tag=j)
            return ctx.suspend(
                lambda evs: sum(float(e.payload) for e in evs),
                n_events=n_puts)
        return fn

    tasks = [ex.spawn(maker(i)) for i in range(n_tasks)]
    stats = ex.run()
    assert stats["events_retired"] == n_tasks * n_puts
    assert stats["tasks_resumed"] == n_tasks
    assert stats["backpressure_stalls"] > 0
    # nothing ever failed to shrink the ledger, so no deferrals
    assert stats["backpressure_deferrals"] == 0
    expect = [sum(range(i * n_puts, (i + 1) * n_puts)) for i in range(n_tasks)]
    assert [t.result for t in tasks] == [float(e) for e in expect]


def test_adaptive_progress_backs_off_when_idle():
    """Progress calls that retire nothing widen the posting interval;
    a retirement snaps it back to the configured progress_every."""
    lcx.init()
    ex = Executor(progress_every=1)
    # compute-only tasks: every interleaved progress retires nothing...
    for i in range(6):
        ex.spawn(lambda ctx: None)
    ex.run()
    assert ex.stats["progress_backoffs"] >= 1
    assert ex._progress_interval > ex.progress_every

    # ...but a communicating task resets the cadence
    def talker(ctx):
        ctx.put(jnp.float32(1.0), None)
        return ctx.suspend(lambda ev: float(ev.payload))

    t = ex.spawn(talker)
    ex.run()
    assert t.result == 1.0
    assert ex._progress_interval == ex.progress_every


def test_adaptive_progress_can_be_disabled():
    lcx.init()
    ex = Executor(progress_every=1, adaptive_progress=False)
    for i in range(4):
        ex.spawn(lambda ctx: None)
    ex.run()
    assert ex.stats["progress_backoffs"] == 0
    assert ex._progress_interval == ex.progress_every


# ---------------------------------------------------------------------------
# Completion objects under executor load (satellite)
# ---------------------------------------------------------------------------
def test_cq_capacity_overflow_from_executor_loop():
    """An under-provisioned retirement queue refuses events with a
    retry status instead of raising from inside progress; a post
    carrying ``max_retries`` re-delivers under backoff once the drain
    frees capacity — and pacing progress per post avoids the overflow
    entirely."""
    lcx.init()
    ex = Executor(cq=lcx.CompletionQueue(capacity=2), progress_every=1000)

    def burst(ctx):
        for i in range(3):
            ctx.put(jnp.float32(i), None, tag=i, max_retries=4)
        return ctx.suspend(lambda evs: len(evs), n_events=3)

    t = ex.spawn(burst)
    ex.run()
    assert t.result == 3
    assert ex.cq.overflows >= 1

    # paced: progress after every post keeps the queue depth at 1
    lcx.init()
    ex2 = Executor(cq=lcx.CompletionQueue(capacity=2), progress_every=1)
    done = []
    for i in range(3):
        def one(ctx, _i=i):
            ctx.put(jnp.float32(_i), None)
            return ctx.suspend(lambda ev: done.append(float(ev.payload)))
        ex2.spawn(one)
    ex2.run()
    assert sorted(done) == [0.0, 1.0, 2.0]


def test_synchronizer_threshold_reset_via_watch():
    """Synchronizer as a *watched* completion object: threshold events
    resolve the promise; wait(reset=True) leaves the surplus queued."""
    lcx.init()
    ex = Executor()
    sync = lcx.Synchronizer(threshold=2)

    def talker(ctx):
        for i in range(3):
            lcx.put_x(jnp.float32(i)).remote_comp(sync) \
                .device(ex.device).tag(i)()
            ex._note_post()

    ex.spawn(talker)
    promise = ex.watch(sync, k=lambda s: s.wait(reset=True))
    ex.run()
    events = promise.result
    assert len(events) == 2
    # one surplus event remains; another signal re-arms the threshold
    assert not sync.ready()
    sync.signal(lcx.Event(payload=None, op="put"))
    assert sync.ready() and len(sync.wait()) == 2


def test_counter_completion_from_executor():
    lcx.init()
    ex = Executor()
    cnt = lcx.CounterCompletion(target=3)

    def talker(ctx):
        for i in range(3):
            lcx.put_x(jnp.float32(i)).remote_comp(cnt) \
                .device(ex.device).tag(i)()
            ex._note_post()

    ex.spawn(talker)
    promise = ex.watch(cnt, k=lambda c: c.count)
    ex.run()
    assert promise.result == 3 and cnt.ready()


def test_completion_objects_concurrent_signaling():
    """signal() from many threads: no events lost (CQ, Counter)."""
    cq = lcx.CompletionQueue(capacity=1 << 16)
    cnt = lcx.CounterCompletion(target=64)
    sync = lcx.Synchronizer(threshold=64)

    def worker(k):
        for i in range(16):
            ev = lcx.Event(payload=None, op="put", tag=k * 16 + i)
            cq.signal(ev)
            cnt.signal(ev)
            sync.signal(ev)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(cq) == 64
    assert cnt.count == 64 and cnt.ready()
    assert sync.ready() and len(sync.wait()) == 64
    assert sorted(e.tag for e in cq.pop_all()) == list(range(64))


# ---------------------------------------------------------------------------
# GPipe as a task graph
# ---------------------------------------------------------------------------
def test_gpipe_taskgraph_matches_sequential_oracle():
    from repro.parallel.pipeline import gpipe
    n_stages = 4
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (n_stages, 8, 8)) / jnp.sqrt(8.0)
    bs = jax.random.normal(jax.random.fold_in(key, 1), (n_stages, 8)) * 0.1
    micro = jax.random.normal(jax.random.fold_in(key, 2), (6, 3, 8))

    def stage_fn(params, x):
        w, b = params
        return jnp.tanh(x @ w + b)

    def per_rank(w, b):
        lcx.init()
        return gpipe(stage_fn, (w, b), micro, axis="pipe")

    out = jax.vmap(per_rank, axis_name="pipe")(ws, bs)
    ref = micro
    for i in range(n_stages):
        ref = jnp.tanh(ref @ ws[i] + bs[i])
    for r in range(n_stages):
        np.testing.assert_allclose(np.asarray(out[r]), np.asarray(ref),
                                   atol=1e-5)


def test_gpipe_taskgraph_grads_match_native():
    """The executor-driven schedule stays differentiable."""
    from repro.parallel.pipeline import gpipe
    n_stages = 4
    ws = jax.random.normal(jax.random.PRNGKey(1), (n_stages, 4, 4)) * 0.3
    micro = jax.random.normal(jax.random.PRNGKey(2), (5, 2, 4))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    def loss(ws_, use_lcx):
        def body(w):
            lcx.init()
            out = gpipe(stage_fn, w, micro, axis="pipe", use_lcx=use_lcx)
            return jnp.sum(out ** 2)
        return jnp.sum(jax.vmap(body, axis_name="pipe")(ws_))

    g_lcx = jax.grad(lambda w: loss(w, True))(ws)
    g_ref = jax.grad(lambda w: loss(w, False))(ws)
    np.testing.assert_allclose(np.asarray(g_lcx), np.asarray(g_ref),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Remote spawning over active messages
# ---------------------------------------------------------------------------
def test_remote_spawn_roundtrips_result_between_neighbors():
    register_task_handler("affine", lambda v: v * 2.0 + 1.0)

    def body(x):
        lcx.init()
        ex = Executor(device=lcx.Device(axis="x"))
        sp = RemoteSpawner(ex)
        promise = sp.spawn("affine", x, lcx.Perm.shift(1))
        ex.run()
        return promise.result

    out = ranked(body)
    # rank r ships x_r to its successor, which computes 2x+1 and replies
    np.testing.assert_allclose(out, 2.0 * np.arange(N) + 1.0)


def test_remote_spawn_no_reply_executes_on_peer():
    calls = []
    register_task_handler("double", lambda v: calls.append(1) or v * 2.0)

    def body(x):
        lcx.init()
        ex = Executor(device=lcx.Device(axis="x"))
        sp = RemoteSpawner(ex)
        assert sp.spawn("double", x, lcx.Perm.shift(1), reply=False) is None
        stats = ex.run()
        assert stats["tasks_run"] == 1     # the handler's execution task
        (t,) = [t for t in ex.graph.tasks.values()
                if t.name == "remote:double"]
        return t.result                    # what the handler computed HERE

    out = ranked(body)
    assert len(calls) == 1                 # one trace = one handler body
    # each rank's handler ran on the *arriving* (predecessor's) payload
    np.testing.assert_allclose(out, 2.0 * np.array([3.0, 0.0, 1.0, 2.0]))


def test_remote_spawn_unknown_handler_raises():
    lcx.init()
    ex = Executor()
    sp = RemoteSpawner(ex)
    with pytest.raises(KeyError):
        sp.spawn("nope", jnp.float32(0), None)
