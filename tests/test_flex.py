"""The objectized flexible function idiom (paper §3.1, Listing 1.1)."""
import pytest

from repro.core.flex import FlexOp, REQUIRED, plain


class foo_x(FlexOp):
    _positional = ("a",)
    _optional = dict(b=10, c=None, d="x")

    def _invoke(self):
        return (self.arg("a"), self.arg("b"), self.arg("c"), self.arg("d"))


def test_positional_and_defaults():
    assert foo_x(1)() == (1, 10, None, "x")


def test_chainable_any_order():
    assert foo_x(1).c(3).b(2)() == (1, 2, 3, "x")
    assert foo_x(1).b(2).c(3)() == (1, 2, 3, "x")
    assert foo_x(1).d("y").b(0).c(9)() == (1, 0, 9, "y")


def test_listing_1_1_shape():
    # D d = foo_x(a1).c(c1)();
    assert foo_x("a1").c("c1")() == ("a1", 10, "c1", "x")


def test_reuse_without_repassing():
    op = foo_x(1).b(5)
    assert op() == (1, 5, None, "x")
    op.c(7)          # tune one more argument
    assert op() == (1, 5, 7, "x")
    assert op() == op()      # stable across calls


def test_late_overrides_do_not_mutate():
    op = foo_x(1).b(5)
    assert op(c=42) == (1, 5, 42, "x")
    assert op() == (1, 5, None, "x")


def test_clone_independent():
    op = foo_x(1).b(5)
    op2 = op.clone().b(6)
    assert op() == (1, 5, None, "x")
    assert op2() == (1, 6, None, "x")


def test_kwargs_constructor():
    assert foo_x(1, b=2, c=3)() == (1, 2, 3, "x")


def test_unknown_argument_rejected():
    with pytest.raises(TypeError):
        foo_x(1, nope=2)
    with pytest.raises(TypeError):
        foo_x(1)(nope=2)


def test_missing_required_positional():
    with pytest.raises(TypeError):
        foo_x()()


def test_too_many_positional():
    with pytest.raises(TypeError):
        foo_x(1, 2)


def test_plain_shorthand():
    foo = plain(foo_x)
    assert foo(1, b=2) == (1, 2, None, "x")
    assert foo.__name__ == "foo"


class req_x(FlexOp):
    _positional = ()
    _optional = dict(must=REQUIRED)

    def _invoke(self):
        return self.arg("must")


def test_required_optional_enforced():
    with pytest.raises(TypeError):
        req_x()()
    assert req_x().must(3)() == 3


def test_repr_mentions_args():
    r = repr(foo_x(1).b(2))
    assert "a=1" in r and "b=2" in r
