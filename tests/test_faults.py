"""Fault tolerance end-to-end: status-carrying completions, injectable
transport faults, retry/timeout/backoff, cancellation, dead-device
drain, and graceful degradation in the AMT executor.

All fault policies are seeded and trace-time, so everything here runs
deterministically on one CPU device (loopback for single-rank paths,
vmap-emulated axes for ranked paths, as in test_core_ops)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx
import repro.amt as amt
from repro.runtime import FailureInjector, NodeFailure, elastic_reshard, \
    fail_device

N = 4


def ranked(fn, n=N):
    return jax.vmap(fn, axis_name="x")(jnp.arange(float(n)))


# ---------------------------------------------------------------------------
# Status-carrying completion objects
# ---------------------------------------------------------------------------
def test_event_status_defaults_ok():
    ev = lcx.Event(payload=1)
    assert ev.status is lcx.ErrorCode.OK
    assert ev.status.ok
    for code in (lcx.ErrorCode.RETRY, lcx.ErrorCode.TIMEOUT,
                 lcx.ErrorCode.CANCELLED, lcx.ErrorCode.FATAL):
        assert not code.ok


def test_synchronizer_surfaces_error_status():
    sync = lcx.Synchronizer(threshold=2)
    sync.signal(lcx.Event(payload=1))
    sync.signal(lcx.Event(payload=None, status=lcx.ErrorCode.FATAL))
    assert sync.ready()
    assert [e.status for e in sync.error_events()] == [lcx.ErrorCode.FATAL]
    with pytest.raises(lcx.CompletionError) as ei:
        sync.wait()
    assert ei.value.events[0].status is lcx.ErrorCode.FATAL
    # events are not consumed by the raise; opting out returns them all
    evs = sync.wait(raise_on_error=False)
    assert [e.status.ok for e in evs] == [True, False]
    assert not sync.ready()


def test_counter_completion_routes_errors():
    cnt = lcx.CounterCompletion(target=2)
    cnt.signal(lcx.Event(payload=1))
    cnt.signal(lcx.Event(payload=None, status=lcx.ErrorCode.TIMEOUT))
    assert cnt.count == 1                  # errors never count as success
    assert cnt.error_count == 1
    assert cnt.errors[0].status is lcx.ErrorCode.TIMEOUT
    assert not cnt.ready()


# ---------------------------------------------------------------------------
# FaultyTransport policies (loopback device exercises the full path)
# ---------------------------------------------------------------------------
def _run_puts(seed, n=20, **rates):
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=seed, **rates))
    cq = lcx.CompletionQueue()
    for i in range(n):
        lcx.put_x(jnp.float32(i)).remote_comp(cq).max_retries(10)()
    for _ in range(200):
        lcx.progress()
        if len(cq) >= n and not lcx.runtime().has_inflight():
            break
    return cq, dict(lcx.runtime().transport.stats)


def test_fault_policy_validation():
    with pytest.raises(ValueError):
        lcx.FaultPolicy(drop=0.8, delay=0.3)
    with pytest.raises(ValueError):
        lcx.FaultPolicy(drop=-0.1)


def test_faulty_transport_deterministic():
    _, s1 = _run_puts(seed=11, drop=0.2, delay=0.1)
    _, s2 = _run_puts(seed=11, drop=0.2, delay=0.1)
    assert s1 == s2
    # per-transfer decision streams: identical for equal seeds,
    # different for different seeds
    mk = lambda seed: lcx.FaultyTransport(seed=seed, drop=0.2, delay=0.1,
                                          duplicate=0.1, corrupt=0.1)
    t1, t2, t3 = mk(11), mk(11), mk(12)
    d1 = [t1.decide() for _ in range(64)]
    d2 = [t2.decide() for _ in range(64)]
    d3 = [t3.decide() for _ in range(64)]
    assert d1 == d2
    assert d1 != d3


def test_drop_with_retries_converges():
    cq, stats = _run_puts(seed=3, drop=0.3)
    assert len(cq) == 20
    assert stats["drops"] > 0
    assert stats["retries"] == stats["drops"]
    assert stats["fatal"] == 0
    assert sorted(float(e.payload) for e in cq.pop_all()) == \
        [float(i) for i in range(20)]


def test_drop_without_retries_is_fatal_not_hang():
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=1, drop=1.0))
    sync = lcx.Synchronizer()
    remote = lcx.Synchronizer()
    h = lcx.put_x(jnp.ones(2)).comp(sync).remote_comp(remote)()
    lcx.progress()
    assert h.status == "fatal"
    # BOTH sides observe the loss — no completion object hangs
    with pytest.raises(lcx.CompletionError):
        sync.wait()
    with pytest.raises(lcx.CompletionError):
        remote.wait()
    assert lcx.runtime().pending_count() == 0


def test_retry_budget_exhaustion_is_fatal():
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=1, drop=1.0))
    sync = lcx.Synchronizer()
    lcx.put_x(jnp.ones(2)).remote_comp(sync).max_retries(3)()
    for _ in range(40):
        lcx.progress()
    (ev,) = sync.wait(raise_on_error=False)
    assert ev.status is lcx.ErrorCode.FATAL
    assert lcx.runtime().transport.stats["fatal"] == 1
    assert not lcx.runtime().has_inflight()


def test_delay_is_bounded_and_converges():
    # pathological always-delay policy still terminates via max_delays
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=0, delay=1.0,
                                              max_delays=4))
    cq = lcx.CompletionQueue()
    lcx.put_x(jnp.float32(7.0)).remote_comp(cq)()
    for _ in range(10):
        lcx.progress()
    assert len(cq) == 1
    assert lcx.runtime().transport.stats["delays"] == 4


def test_duplicate_delivers_twice():
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=0, duplicate=1.0))
    cq = lcx.CompletionQueue()
    lcx.put_x(jnp.float32(5.0)).remote_comp(cq)()
    lcx.progress()
    evs = cq.pop_all()
    assert len(evs) == 2
    assert all(float(e.payload) == 5.0 for e in evs)


def test_corrupt_marks_retry_status_and_flips_bits():
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=0, corrupt=1.0))
    cq = lcx.CompletionQueue()
    lcx.put_x(jnp.float32(1.0)).remote_comp(cq)()
    lcx.progress()
    ev = cq.pop()
    assert ev.status is lcx.ErrorCode.RETRY       # detected corruption
    assert float(ev.payload) != 1.0               # bitwise-NOT of payload
    # silent corruption: same payload damage, but status stays ok
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=0, corrupt=1.0,
                                              corrupt_mark=False))
    cq = lcx.CompletionQueue()
    lcx.put_x(jnp.float32(1.0)).remote_comp(cq)()
    lcx.progress()
    ev = cq.pop()
    assert ev.status.ok
    assert float(ev.payload) != 1.0


# ---------------------------------------------------------------------------
# Op lifecycle: timeout, cancel
# ---------------------------------------------------------------------------
def test_unmatched_recv_times_out():
    lcx.init()
    cq = lcx.CompletionQueue()
    h = lcx.recv_x(jnp.zeros(2)).tag(9).comp(cq).timeout(3)()
    lcx.progress()
    assert h.status == "pending"
    for _ in range(4):
        lcx.progress()
    assert h.status == "timeout"
    ev = cq.pop()
    assert ev.status is lcx.ErrorCode.TIMEOUT
    # the op was retired from the engine, not leaked
    assert lcx.runtime().default_engine.pending() == (0, 0)


def test_cancel_pending_send():
    lcx.init()
    sync = lcx.Synchronizer()
    h = lcx.send_x(jnp.zeros(2)).tag(4).comp(sync)()
    assert h.status == "pending"
    assert h.cancel() is True
    assert h.status == "cancelled"
    assert h.cancel() is False            # idempotent: already retired
    (ev,) = sync.wait(raise_on_error=False)
    assert ev.status is lcx.ErrorCode.CANCELLED
    assert lcx.runtime().default_engine.pending() == (0, 0)


def test_cancel_after_match_fails():
    lcx.init()
    h = lcx.send_x(jnp.float32(1.0)).tag(1)()
    lcx.recv_x(jnp.float32(0.0)).tag(1)()
    assert h.status == "matched"
    assert h.cancel() is False


def test_pending_exact_after_cancel():
    """Satellite regression: cancelled entries must leave the engine's
    pending() counts exact, in keyed buckets, FIFO queues, and the
    unhashable-key overflow list."""
    lcx.init()
    eng = lcx.runtime().default_engine
    hs = [lcx.send_x(jnp.float32(i)).tag(i)() for i in range(4)]
    assert eng.pending() == (4, 0)
    assert hs[1].cancel() and hs[2].cancel()
    assert eng.pending() == (2, 0)
    # remaining sends still match their recvs
    for i in (0, 3):
        lcx.recv_x(jnp.float32(0.0)).tag(i)()
    assert eng.pending() == (0, 0)
    lcx.progress()

    # queue kind
    lcx.init()
    qeng = lcx.MatchingEngine(kind="queue", policy="tag_only")
    h1 = lcx.send_x(jnp.float32(1.0)).tag(1).matching_engine(qeng)()
    h2 = lcx.send_x(jnp.float32(2.0)).tag(2).matching_engine(qeng)()
    assert qeng.pending() == (2, 0)
    assert h1.cancel()
    assert qeng.pending() == (1, 0)
    # FIFO head is now the surviving send (tag 2)
    lcx.recv_x(jnp.float32(0.0)).tag(2).matching_engine(qeng)()
    assert qeng.pending() == (0, 0)

    # unhashable custom keys take the overflow-list path
    lcx.init()
    ueng = lcx.MatchingEngine(policy="custom",
                              key_fn=lambda op: [op.tag])
    h1 = lcx.send_x(jnp.float32(1.0)).tag(1).matching_engine(ueng)()
    h2 = lcx.send_x(jnp.float32(2.0)).tag(2).matching_engine(ueng)()
    assert ueng.pending() == (2, 0)
    assert h1.cancel()
    assert ueng.pending() == (1, 0)
    lcx.recv_x(jnp.float32(0.0)).tag(2).matching_engine(ueng)()
    assert ueng.pending() == (0, 0)


# ---------------------------------------------------------------------------
# Dead devices: NodeFailure -> fatal drain -> elastic_reshard
# ---------------------------------------------------------------------------
def test_dead_device_drains_fatal():
    lcx.init()
    dev = lcx.Device()
    sync = lcx.Synchronizer()
    lcx.put_x(jnp.ones(2)).remote_comp(sync).device(dev)()
    assert fail_device(dev) == 1
    assert not dev.alive
    (ev,) = sync.wait(raise_on_error=False)
    assert ev.status is lcx.ErrorCode.FATAL
    assert lcx.runtime().pending_count() == 0
    # posting again to the dead device also drains as fatal at progress
    sync2 = lcx.Synchronizer()
    lcx.put_x(jnp.ones(2)).remote_comp(sync2).device(dev)()
    lcx.progress()
    (ev2,) = sync2.wait(raise_on_error=False)
    assert ev2.status is lcx.ErrorCode.FATAL


def test_node_failure_feeds_elastic_reshard():
    """The ISSUE's end-to-end story: an injected NodeFailure kills the
    device, pending comm drains fatal (nobody hangs), and live state
    moves on via elastic_reshard."""
    lcx.init()
    dev = lcx.Device()
    sync = lcx.Synchronizer()
    lcx.put_x(jnp.arange(4.0)).remote_comp(sync).device(dev)()
    inj = FailureInjector(fail_at=[2], lost_devices=1, devices=[dev])
    state = {"w": jnp.arange(8.0)}
    inj.check(1)
    with pytest.raises(NodeFailure):
        inj.check(2)
    (ev,) = sync.wait(raise_on_error=False)
    assert ev.status is lcx.ErrorCode.FATAL
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    new_state = elastic_reshard(state, {"w": sh})
    np.testing.assert_array_equal(np.asarray(new_state["w"]),
                                  np.arange(8.0))


# ---------------------------------------------------------------------------
# Graceful degradation in the AMT executor
# ---------------------------------------------------------------------------
def test_executor_fail_fast_default_still_raises():
    lcx.init()
    ex = amt.Executor()
    ex.spawn(lambda ctx: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        ex.run()


def test_executor_graceful_retry_then_success():
    lcx.init()
    ex = amt.Executor(fail_fast=False, max_task_retries=3,
                      task_retry_backoff=1)
    calls = {"n": 0}

    def flaky(ctx):
        calls["n"] += 1
        if calls["n"] < 3:
            raise ValueError("flaky")
        return 42

    t = ex.spawn(flaky)
    ex.run()
    assert t.result == 42
    assert t.state is amt.TaskState.DONE
    st = ex.status_of(t)
    assert st.attempts == 2 and st.state == "retrying"
    assert ex.stats["task_retries"] == 2
    assert not ex.dead_letter


def test_executor_dead_letter_and_cascade():
    lcx.init()
    ex = amt.Executor(fail_fast=False, max_task_retries=1)
    ok = ex.spawn(lambda ctx: "fine")

    def hopeless(ctx):
        raise ValueError("always")

    bad = ex.spawn(hopeless)
    child = ex.spawn(lambda ctx: 1, deps=(bad,))
    stats = ex.run()                       # does NOT raise
    assert ok.result == "fine"
    assert bad.state is amt.TaskState.FAILED
    assert ex.dead_letter == [bad]
    assert ex.status_of(bad).state == "failed"
    assert ex.status_of(bad).attempts == 2          # 1 try + 1 retry
    # the dependent can never run: cascade-failed with a DependencyError
    assert child.state is amt.TaskState.FAILED
    assert ex.status_of(child).state == "cascade"
    assert isinstance(child.error, amt.DependencyError)
    assert stats["tasks_failed"] == 2


def test_executor_survives_faulty_transport():
    """Pipeline-ish workload: chained tasks communicating over a lossy
    loopback transport complete correctly via comm retries, with the
    executor's deadlock detector tolerating in-flight backoff."""
    lcx.init()
    lcx.install_transport(lcx.FaultyTransport(seed=5, drop=0.1, delay=0.1))
    ex = amt.Executor(fail_fast=False)
    results = []

    def stage(ctx, i):
        ctx.put(jnp.float32(i), None, tag=i, max_retries=8)
        return ctx.suspend(lambda ev: results.append(float(ev.payload)))

    prev = None
    for i in range(8):
        prev = ex.spawn(lambda ctx, _i=i: stage(ctx, _i),
                        deps=(prev,) if prev else ())
    ex.run()
    assert sorted(results) == [float(i) for i in range(8)]
    assert lcx.runtime().transport.stats["drops"] > 0


def test_executor_comm_timeout_event_not_teardown():
    """An unmatched recv with a deadline resumes its task with a
    timeout-status event — the executor keeps running, nothing hangs."""
    lcx.init()
    ex = amt.Executor()
    seen = []

    def waiter(ctx):
        ctx.recv(jnp.zeros(2), None, tag=99, timeout=3)
        return ctx.suspend(lambda ev: seen.append(ev.status))

    after = ex.spawn(lambda ctx: "ran", deps=(ex.spawn(waiter),))
    ex.run()
    assert seen == [lcx.ErrorCode.TIMEOUT]
    assert after.result == "ran"


# ---------------------------------------------------------------------------
# Remote spawning error replies
# ---------------------------------------------------------------------------
def test_remote_unknown_handler_resolves_remote_failure():
    lcx.init()
    amt.clear_task_handlers()
    ex = amt.Executor()
    sp = amt.RemoteSpawner(ex)
    amt.register_task_handler("ghost", lambda p: p)
    promise = sp.spawn("ghost", jnp.float32(1.0), lcx.Perm.shift(0))
    # simulate the handler missing on the destination rank
    amt.clear_task_handlers()
    ex.run()
    res = promise.result
    assert isinstance(res, amt.RemoteFailure)
    assert res.status == "unknown_handler" and not res.ok
    assert sp.stats["unknown_handlers"] == 1


def test_remote_handler_exception_resolves_remote_failure():
    lcx.init()
    amt.clear_task_handlers()
    ex = amt.Executor()
    sp = amt.RemoteSpawner(ex)
    amt.register_task_handler("boom", lambda p: 1 / 0)
    amt.register_task_handler("double", lambda p: p * 2)
    p_bad = sp.spawn("boom", jnp.float32(1.0), lcx.Perm.shift(0))
    p_ok = sp.spawn("double", jnp.float32(3.0), lcx.Perm.shift(0))
    ex.run()
    assert isinstance(p_bad.result, amt.RemoteFailure)
    assert p_bad.result.status == "handler_error"
    assert "ZeroDivisionError" in p_bad.result.message
    assert float(p_ok.result) == 6.0      # healthy traffic unaffected
    assert sp.stats["handler_errors"] == 1
    amt.clear_task_handlers()


# ---------------------------------------------------------------------------
# Ranked (vmap-emulated axis) acceptance: pingpong under 10% faults
# ---------------------------------------------------------------------------
def test_ranked_pingpong_under_seeded_faults():
    """Acceptance criterion: a ring pingpong under 10% seeded drop +
    10% delay completes with correct results via retries."""

    def body(x):
        lcx.init()
        lcx.install_transport(lcx.FaultyTransport(seed=7, drop=0.1,
                                                  delay=0.1))
        dev = lcx.Device(axis="x")
        ping = lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(ping) \
            .device(dev).max_retries(8)()
        for _ in range(64):
            lcx.progress()
            if ping.ready() and not lcx.runtime().has_inflight():
                break
        (ev,) = ping.wait()
        assert ev.status.ok
        pong = lcx.Synchronizer()
        lcx.put_x(ev.payload).perm(lcx.Perm.shift(-1)).remote_comp(pong) \
            .device(dev).max_retries(8)()
        for _ in range(64):
            lcx.progress()
            if pong.ready() and not lcx.runtime().has_inflight():
                break
        (ev2,) = pong.wait()
        assert ev2.status.ok
        return ev2.payload

    out = ranked(body)
    # ping shifts my value right, pong returns it: identity round trip
    np.testing.assert_allclose(np.asarray(out), np.arange(float(N)))
