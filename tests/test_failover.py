"""Elastic endpoint migration: device death with live failover.

Covers the failover subsystem end to end: ``NetContext.migrate`` /
``runtime.failover`` (endpoint re-homing, ledger + retry-queue + pending
op transplant, sequence-number replay with dedup), the progress-tick
:class:`HeartbeatMonitor` and its ``on_dead`` policies, AMT executor
re-dispatch of migrated completions, the gpipe schedule surviving a
stage-device kill, and the serving engine's failover wiring.

All scenarios are seeded and trace-time (loopback + vmap-emulated axes,
as in test_faults), so a "device kill" is ``device.freeze()`` — the
device stops beating/progressing but its state is intact, exactly the
silent-death case the heartbeat exists for."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx
from repro.amt import Executor
from repro.runtime import HeartbeatMonitor, NodeFailure


def drain(rt, cq, want, max_ticks=400):
    for _ in range(max_ticks):
        lcx.progress()
        if len(cq) >= want and not rt.has_inflight():
            break
    return cq.pop_all()


def fresh_pair():
    lcx.init()
    rt = lcx.runtime()
    return rt, rt.device(), rt.device()


# ---------------------------------------------------------------------------
# Acceptance: kill one of two devices mid-pingpong under 10% drop
# ---------------------------------------------------------------------------
def test_kill_one_of_two_devices_mid_pingpong():
    rt, ping, pong = fresh_pair()
    lcx.install_transport(lcx.FaultyTransport(seed=11, drop=0.1))
    hb = HeartbeatMonitor(threshold=2.0, patience=2, grace=3,
                          on_dead="failover").attach(rt)
    for _ in range(4):
        lcx.progress()                      # beat history for the EMA
    cq = lcx.CompletionQueue()
    n = 24
    # pingpong: alternate the posting side every transfer
    for i in range(n):
        dev = ping if i % 2 == 0 else pong
        lcx.put_x(jnp.float32(i)).remote_comp(cq).device(dev) \
            .tag(i).max_retries(32)()
    # every transfer is in flight (drop retries included) when the ping
    # side dies silently — delivery REQUIRES the failover to happen
    ping.freeze()
    evs = drain(rt, cq, n)
    got = sorted(float(ev.payload) for ev in evs)
    # exactly once: no transfer lost, none double-delivered
    assert got == [float(i) for i in range(n)], got
    assert len(hb.events) == 1 and hb.events[0]["device"] is ping
    assert not ping.alive and ping.migrated_to is not None
    assert ping.migrated_to.alive
    assert rt.failover_stats["failovers"] == 1


def test_migrated_flag_set_on_replayed_deliveries():
    rt, a, b = fresh_pair()
    cq = lcx.CompletionQueue()
    for i in range(4):
        lcx.put_x(jnp.float32(i)).remote_comp(cq).device(a).tag(i)()
    a.freeze()
    rt.failover(a, target=b)
    evs = drain(rt, cq, 4)
    assert [ev.migrated for ev in evs] == [True] * 4
    assert sorted(float(e.payload) for e in evs) == [0.0, 1.0, 2.0, 3.0]


def test_unmatched_send_migrates_and_matches_on_target():
    rt, a, b = fresh_pair()
    scq, rcq = lcx.CompletionQueue(), lcx.CompletionQueue()
    lcx.send_x(jnp.float32(42.0)).comp(scq).device(a).tag(9)()
    a.freeze()
    rep = rt.failover(a, target=b)
    assert rep.n_engine_ops == 1            # transplanted while pending
    # the match key (tag/rank) survived: a recv on the TARGET matches it
    lcx.recv_x(jnp.zeros((), jnp.float32)).comp(rcq).device(b).tag(9)()
    evs = drain(rt, rcq, 1)
    assert float(evs[0].payload) == 42.0 and evs[0].migrated


def test_failover_picks_least_loaded_survivor():
    lcx.init()
    rt = lcx.runtime()
    a, busy, idle = rt.device(), rt.device(), rt.device()
    cq = lcx.CompletionQueue()
    for i in range(5):                      # load the busy candidate
        lcx.put_x(jnp.float32(i)).remote_comp(cq).device(busy).tag(i)()
    assert rt.pending_for(busy) > rt.pending_for(idle)
    a.freeze()
    rep = rt.failover(a)
    assert rep.target is not busy and rep.target is not a


def test_failover_without_survivor_raises():
    lcx.init(alloc_default_resources=False)
    rt = lcx.runtime()
    a = rt.device()
    a.freeze()
    with pytest.raises(RuntimeError, match="no alive device"):
        rt.failover(a)


def test_resolve_resources_follows_migration_chain():
    rt, a, b = fresh_pair()
    a.freeze()
    rt.failover(a, target=b)
    assert a.resolve_migrated() is b
    # ops explicitly targeting the dead device re-route to the survivor
    cq = lcx.CompletionQueue()
    lcx.put_x(jnp.float32(1.0)).remote_comp(cq).device(a).tag(0)()
    evs = drain(rt, cq, 1)
    assert float(evs[0].payload) == 1.0


# ---------------------------------------------------------------------------
# Heartbeat policies
# ---------------------------------------------------------------------------
def _stalled_runtime(policy, **kw):
    lcx.init()
    rt = lcx.runtime()
    a, b = rt.device(), rt.device()
    hb = HeartbeatMonitor(threshold=2.0, patience=2, grace=3,
                          on_dead=policy, **kw).attach(rt)
    for _ in range(4):
        lcx.progress()
    cq = lcx.CompletionQueue()
    for i in range(3):
        lcx.put_x(jnp.float32(i)).remote_comp(cq).device(a).tag(i)()
    a.freeze()
    return rt, a, b, hb, cq


def test_heartbeat_policy_drain_surfaces_fatal():
    rt, a, _, hb, cq = _stalled_runtime("drain")
    for _ in range(40):
        lcx.progress()
        if len(cq) >= 3:
            break
    evs = cq.pop_all()
    assert {ev.status for ev in evs} == {lcx.ErrorCode.FATAL}
    assert not a.alive and a.migrated_to is None
    assert hb.events[0]["policy"] == "drain"


def test_heartbeat_policy_raise():
    rt, a, _, hb, cq = _stalled_runtime("raise")
    with pytest.raises(NodeFailure, match="heartbeat lost"):
        for _ in range(40):
            lcx.progress()
    assert not a.alive


def test_heartbeat_ignores_healthy_jitter():
    lcx.init()
    rt = lcx.runtime()
    rt.device(), rt.device()
    hb = HeartbeatMonitor(threshold=2.0, patience=2, grace=3).attach(rt)
    for _ in range(50):
        lcx.progress()
    assert hb.events == []
    assert rt.failover_stats["failovers"] == 0


def test_invalid_heartbeat_policy_rejected():
    with pytest.raises(ValueError, match="on_dead"):
        HeartbeatMonitor(on_dead="shrug")


# ---------------------------------------------------------------------------
# Acceptance: executor drains a TaskGraph with zero dead-letters
# ---------------------------------------------------------------------------
def test_executor_drains_taskgraph_under_automatic_failover():
    lcx.init()
    rt = lcx.runtime()
    primary, standby = rt.device(), rt.device()
    HeartbeatMonitor(threshold=2.0, patience=2, grace=3,
                     on_dead="failover").attach(rt)
    for _ in range(4):
        lcx.progress()
    ex = Executor(name="fo", runtime=rt, device=primary, fail_fast=False)
    got = []

    def worker(ctx, i):
        ctx.put(jnp.float32(i), None, tag=i, max_retries=16)
        return ctx.suspend(lambda ev: got.append(float(ev.payload)))

    # mid-graph kill: half the workers post before the freeze, half
    # after — both populations must complete on the survivor
    for i in range(4):
        ex.spawn(lambda ctx, _i=i: worker(ctx, _i), priority=4,
                 name=f"w{i}")
    ex.spawn(lambda ctx: primary.freeze(), priority=2, name="killer")
    for i in range(4, 8):
        ex.spawn(lambda ctx, _i=i: worker(ctx, _i), priority=0,
                 name=f"w{i}")
    stats = ex.run()
    assert sorted(got) == [float(i) for i in range(8)]
    assert ex.dead_letter == []             # zero dead-letters
    assert rt.failover_stats["failovers"] == 1
    assert not primary.alive
    assert ex.device is primary.resolve_migrated()  # executor re-homed


def test_executor_redispatches_on_nonreplayable_migration():
    """replay=False migration completes suspended ops as RETRY+migrated;
    the executor re-runs those tasks instead of dead-lettering them."""
    lcx.init()
    rt = lcx.runtime()
    primary = rt.device()
    rt.device(axis=None)                    # survivor
    ex = Executor(name="rd", runtime=rt, device=primary, fail_fast=False)
    got = []

    def worker(ctx, i):
        ctx.put(jnp.float32(i), None, tag=i)
        return ctx.suspend(lambda ev: got.append(float(ev.payload)))

    for i in range(4):
        ex.spawn(lambda ctx, _i=i: worker(ctx, _i), name=f"w{i}")

    def killer(ctx):
        primary.freeze()
        rt.failover(primary, replay=False)

    ex.spawn(killer, priority=-5, name="killer")
    stats = ex.run()
    assert sorted(got) == [0.0, 1.0, 2.0, 3.0]
    assert stats["tasks_redispatched"] == 4
    assert ex.dead_letter == []


def test_executor_backpressure_is_per_device():
    """A busy neighbour device's backlog must not stall admission on the
    executor's own device (satellite: pending_for, not pending_count)."""
    lcx.init()
    rt = lcx.runtime()
    mine, neighbour = rt.device(), rt.device()
    ncq = lcx.CompletionQueue()
    for i in range(32):                     # backlog on the neighbour
        lcx.put_x(jnp.float32(i)).remote_comp(ncq).device(neighbour) \
            .tag(i)()
    ex = Executor(name="bp", runtime=rt, device=mine, max_inflight=8)
    got = []

    def worker(ctx, i):
        ctx.put(jnp.float32(i), None, tag=i)
        return ctx.suspend(lambda ev: got.append(float(ev.payload)))

    for i in range(4):
        ex.spawn(lambda ctx, _i=i: worker(ctx, _i), name=f"w{i}")
    stats = ex.run()
    assert sorted(got) == [0.0, 1.0, 2.0, 3.0]
    # 4 in-flight on `mine` never reached the limit of 8, even though
    # the neighbour held 32 pending the whole time
    assert stats["backpressure_stalls"] == 0


# ---------------------------------------------------------------------------
# Satellite: cancel / retry-budget / dedup-window edges across migration
# ---------------------------------------------------------------------------
def test_cancel_across_migration():
    rt, a, b = fresh_pair()
    scq = lcx.CompletionQueue()
    h = lcx.send_x(jnp.float32(1.0)).comp(scq).device(a).tag(5)()
    a.freeze()
    rt.failover(a, target=b)
    # mid-migration snapshot: an op whose engine pointer is cleared (the
    # extract→re-post window) refuses cancellation instead of crashing
    op = h.posted
    eng, op.engine = op.engine, None
    assert h.cancel() is False
    op.engine = eng
    # after migration the op pends in the TARGET engine: cancel works
    assert h.cancel() is True
    assert h.status == "cancelled"
    evs = scq.pop_all()
    assert evs[-1].status is lcx.ErrorCode.CANCELLED
    # cancelled op never matches a later recv on the target
    rcq = lcx.CompletionQueue()
    lcx.recv_x(jnp.zeros((), jnp.float32)).comp(rcq).device(b) \
        .tag(5).timeout(8)()
    for _ in range(12):
        lcx.progress()
        if len(rcq):
            break
    assert rcq.pop_all()[0].status is lcx.ErrorCode.TIMEOUT


def test_max_retries_budget_preserved_across_migration():
    rt, a, b = fresh_pair()
    lcx.install_transport(lcx.FaultyTransport(seed=3, drop=1.0))
    cq = lcx.CompletionQueue()
    h = lcx.put_x(jnp.float32(7.0)).remote_comp(cq).device(a) \
        .max_retries(6)()
    for _ in range(3):                      # burn part of the budget
        lcx.progress()
    burned = h.posted.retries
    assert burned > 0
    a.freeze()
    rt.failover(a, target=b)
    assert h.posted.retries == burned       # migration did not reset it
    for _ in range(300):
        lcx.progress()
        if len(cq):
            break
    assert cq.pop_all()[0].status is lcx.ErrorCode.FATAL
    assert h.posted.retries == 6            # exhausted the ORIGINAL budget


def test_dedup_window_evicts_at_boundary():
    rt = lcx.Runtime(name="w", alloc_default_resources=False,
                     dedup_window=4)
    for seq in range(1, 6):                 # 5 deliveries, window of 4
        rt.note_delivered(seq)
    assert not rt.was_delivered(1)          # evicted: boundary crossed
    assert all(rt.was_delivered(s) for s in range(2, 6))
    assert not rt.was_delivered(99)


def test_replayed_migrated_delivery_suppressed():
    """A transfer that raced the failure — delivered, then replayed by
    the failover — is suppressed by the dedup window (exactly once)."""
    rt, a, b = fresh_pair()
    scq, rcq = lcx.CompletionQueue(), lcx.CompletionQueue()
    hs = lcx.send_x(jnp.float32(3.0)).comp(scq).device(a).tag(1)()
    hr = lcx.recv_x(jnp.zeros((), jnp.float32)).comp(rcq).device(a) \
        .tag(1)()
    evs = drain(rt, rcq, 1)
    assert len(evs) == 1                    # delivered once, seq noted
    scq.pop_all()
    # simulate the race: the failover re-homes and replays the pair
    s, r = hs.posted, hr.posted
    s.migrated = r.migrated = True
    s.device = r.device = b
    rt.enqueue_matches([(s, r)])
    for _ in range(5):
        lcx.progress()
    assert len(rcq) == 0                    # replay suppressed
    assert len(scq) == 0                    # sender not re-signalled
    assert rt.failover_stats["dedup_suppressed"] == 1


def test_dedup_window_boundary_allows_evicted_replay():
    """Replays older than the window pass through — the window bounds
    the exactly-once guarantee (and the suppression state's memory)."""
    rt = lcx.Runtime(name="wb", dedup_window=2)
    dev = rt.device()
    rcqs = []
    pairs = []
    for i in range(3):
        scq, rcq = lcx.CompletionQueue(), lcx.CompletionQueue()
        hs = lcx.send_x(jnp.float32(i)).comp(scq).device(dev).tag(i) \
            .runtime(rt)()
        hr = lcx.recv_x(jnp.zeros((), jnp.float32)).comp(rcq) \
            .device(dev).tag(i).runtime(rt)()
        rcqs.append(rcq)
        pairs.append((hs.posted, hr.posted))
    for _ in range(10):
        lcx.progress_x().runtime(rt)()
        if all(len(q) for q in rcqs):
            break
    for q in rcqs:
        q.pop_all()
    # seq of pair 0 was evicted by deliveries 1 and 2 (window of 2):
    # its replay is NOT suppressed; pair 2 is still in-window
    for s, r in (pairs[0], pairs[2]):
        s.migrated = r.migrated = True
        rt.enqueue_matches([(s, r)])
    for _ in range(5):
        lcx.progress_x().runtime(rt)()
    assert len(rcqs[0]) == 1                # evicted → replay delivered
    assert len(rcqs[2]) == 0                # in-window → suppressed
    assert rt.failover_stats["dedup_suppressed"] == 1


def test_unmigrated_duplicates_still_deliver_twice():
    """The dedup window guards MIGRATED ops only: plain transport
    duplicates keep their at-least-once semantics (chaosbench counts
    extra deliveries)."""
    lcx.init()
    rt = lcx.runtime()
    lcx.install_transport(lcx.FaultyTransport(seed=5, duplicate=1.0))
    cq = lcx.CompletionQueue()
    lcx.put_x(jnp.float32(1.0)).remote_comp(cq).tag(0)()
    for _ in range(20):
        lcx.progress()
        if len(cq) >= 2:
            break
    evs = cq.pop_all()
    assert len(evs) == 2
    assert rt.failover_stats["dedup_suppressed"] == 0


# ---------------------------------------------------------------------------
# gpipe + serving get the same treatment
# ---------------------------------------------------------------------------
def test_gpipe_schedule_survives_stage_device_kill():
    from repro.parallel.pipeline import gpipe
    n_stages = 4
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (n_stages, 8, 8)) / jnp.sqrt(8.0)
    micro = jax.random.normal(jax.random.fold_in(key, 2), (6, 3, 8))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    rt = lcx.Runtime(name="gp-fo")
    dev = rt.device(axis="pipe")
    dev.freeze()                            # primary dead before tick 0

    def per_rank(w):
        return gpipe(stage_fn, w, micro, axis="pipe", runtime=rt,
                     device=dev, failover=True)

    out = jax.vmap(per_rank, axis_name="pipe")(ws)
    ref = micro
    for i in range(n_stages):
        ref = jnp.tanh(ref @ ws[i])
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref),
                               atol=1e-5)
    assert rt.failover_stats["failovers"] == 1
    assert not dev.alive and dev.migrated_to is not None


def test_serving_engine_failover_wiring():
    from repro.configs.base import ModelConfig
    from repro.models import init_model
    from repro.serving import Request, ServeConfig, ServingEngine
    cfg = ModelConfig(name="d", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=97,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      q_block=8)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(n_slots=2, max_seq=32,
                                                 max_new_tokens=3),
                        failover=True)
    assert eng.heartbeat is not None
    assert eng.lcx_runtime.heartbeat is eng.heartbeat
    assert eng.standby_device is not None and eng.standby_device.alive
    # a frozen serving device must not wedge the tick loop
    eng._executor.device.freeze()
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32)))
    done = eng.run_until_drained()
    assert len(done) == 1 and done[0].error is None
