"""Pallas kernels vs ref.py oracles — shape/dtype sweeps (interpret
mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention as flash_pallas
from repro.kernels.moe_gmm import moe_gmm as gmm_pallas


FLASH_CASES = [
    # (b, hq, hkv, sq, sk, dk, dv, causal, dtype)
    (2, 4, 2, 128, 128, 64, 64, True, jnp.float32),
    (1, 8, 8, 256, 256, 128, 128, True, jnp.float32),
    (2, 4, 2, 64, 192, 32, 32, False, jnp.float32),
    (1, 6, 2, 96, 96, 64, 32, True, jnp.float32),
    (1, 4, 4, 128, 128, 64, 64, True, jnp.bfloat16),
    (2, 2, 1, 64, 64, 16, 16, False, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_kernel(case):
    b, hq, hkv, sq, sk, dk, dv, causal, dtype = case
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, sq, dk), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, sk, dk), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, sk, dv), dtype)
    out = flash_pallas(q, k, v, causal=causal, block_q=64, block_k=64,
                       interpret=True)
    ref = kref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


SSD_CASES = [
    # (b, s, h, p, n, chunk, dtype)
    (2, 64, 3, 16, 8, 16, jnp.float32),
    (1, 128, 2, 32, 16, 32, jnp.float32),
    (1, 32, 4, 8, 4, 8, jnp.float32),
    (2, 64, 2, 16, 8, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_kernel(case):
    b, s, h, p, n, chunk, dtype = case
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(key, 1), (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, n), dtype)
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, h, n), dtype)
    y, hf = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, backend="pallas")
    y_ref, h_ref = kref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref),
                               atol=tol)


GMM_CASES = [
    (4, 64, 96, 80, jnp.float32),
    (2, 128, 64, 64, jnp.float32),
    (8, 32, 48, 32, jnp.bfloat16),
    (1, 256, 128, 256, jnp.float32),
]


@pytest.mark.parametrize("case", GMM_CASES)
def test_moe_gmm_kernel(case):
    e, c, d, f, dtype = case
    xb = jax.random.normal(jax.random.PRNGKey(0), (e, c, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, d, f), dtype)
    out = gmm_pallas(xb, w, block_c=32, block_f=32, block_d=32,
                     interpret=True)
    ref = kref.moe_gmm_ref(xb, w)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=1e-2)


def test_backend_auto_resolves_to_xla_on_cpu():
    assert not ops.on_tpu()
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 16, 8))
    out = ops.flash_attention(q, q, q, causal=True)    # backend=None
    ref = kref.flash_attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_model_kernels_hooks_match_model_layout():
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab=64, dtype=jnp.float32,
                      param_dtype=jnp.float32, q_block=16)
    ks = ops.model_kernels(cfg, backend="pallas")
    b, s = 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, 2, 16))
    out = ks["flash_attention"](q, k, v, causal=True, scale=0.25)
    ref = kref.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2), causal=True, scale=0.25)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(ref, 1, 2)),
                               atol=2e-5)
