"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core as lcx
from repro.core.resources import MatchingEngine, PostedOp
from repro.models.moe import capacity, combine, dispatch
from repro.optim import compress_int8, decompress_int8


# ---------------------------------------------------------------------------
# matching engine: posting order invariance (map engine)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 3)),
                min_size=2, max_size=16),
       st.randoms(use_true_random=False))
def test_map_engine_order_invariant(ops, rnd):
    """For the map engine, the multiset of matched (send_tag, recv_tag)
    pairs is independent of posting order."""
    lcx.init()
    dev = lcx.Device()

    def run(seq):
        eng = MatchingEngine(kind="map", policy="tag_only")
        matches = []
        for i, (is_send, tag) in enumerate(seq):
            op = PostedOp(kind="send" if is_send else "recv", buffer=None,
                          perm=None, tag=tag, comp=None, device=dev, seq=i)
            matches += eng.post(op)
        return sorted((s.tag, r.tag) for s, r in matches), eng.pending()

    base_matches, base_pending = run(ops)
    shuffled = list(ops)
    rnd.shuffle(shuffled)
    m2, p2 = run(shuffled)
    assert base_matches == m2
    assert base_pending == p2


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=12))
def test_queue_engine_fifo_same_tag(tags):
    """With a single tag stream, the queue engine matches sends and
    recvs 1:1 in FIFO order."""
    lcx.init()
    dev = lcx.Device()
    eng = MatchingEngine(kind="queue", policy="none")
    n = 0
    for i, t in enumerate(tags):
        n += len(eng.post(PostedOp(kind="send", buffer=i, perm=None,
                                   tag=t, comp=None, device=dev, seq=i)))
    for i, t in enumerate(tags):
        n += len(eng.post(PostedOp(kind="recv", buffer=None, perm=None,
                                   tag=t, comp=None, device=dev,
                                   seq=100 + i)))
    assert n == len(tags)
    assert eng.pending() == (0, 0)


# ---------------------------------------------------------------------------
# flex ops: argument order invariance
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.permutations(["b", "c", "d"]))
def test_flex_setter_order_invariant(order):
    from repro.core.flex import FlexOp

    class f_x(FlexOp):
        _positional = ("a",)
        _optional = dict(b=None, c=None, d=None)

        def _invoke(self):
            return tuple(self.arg(k) for k in ("a", "b", "c", "d"))

    op = f_x(0)
    for i, name in enumerate(order):
        getattr(op, name)(i)
    vals = dict(zip(order, range(3)))
    assert op() == (0, vals["b"], vals["c"], vals["d"])


# ---------------------------------------------------------------------------
# Perm algebra
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(-8, 8))
def test_perm_shift_inverse(n, k):
    p = lcx.Perm.shift(k)
    inv = p.inverse()
    pairs = dict(p.pairs_for(n))
    inv_pairs = dict(inv.pairs_for(n))
    for s, d in pairs.items():
        assert inv_pairs[d] == s


# ---------------------------------------------------------------------------
# MoE dispatch/combine invariants
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6).flatmap(
    lambda e: st.tuples(st.just(e), st.integers(1, 24),
                        st.integers(1, min(e, 3)))))
def test_dispatch_combine_identity(params):
    """With capacity >= all tokens, combine(dispatch(x)) with weights
    summing to 1 reconstructs x exactly."""
    E, T, k = params
    d = 8
    key = jax.random.PRNGKey(T * 31 + E)
    x = jax.random.normal(key, (T, d), jnp.float32)
    ids = jax.random.randint(jax.random.fold_in(key, 1), (T, k), 0, E)
    w = jnp.ones((T, k), jnp.float32) / k
    C = T * k  # no drops possible
    buf, info = dispatch(x, ids, w, E, C)
    y = combine(buf, info, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(4, 64))
def test_dispatch_capacity_drop_bound(E, T):
    """No expert ever receives more than C tokens."""
    k = 2
    d = 4
    key = jax.random.PRNGKey(T + E)
    x = jnp.ones((T, d), jnp.float32)
    ids = jax.random.randint(key, (T, k), 0, E)
    w = jnp.ones((T, k)) / k
    C = max(1, (T * k) // (2 * E))
    buf, info = dispatch(x, ids, w, E, C)
    # buf rows are either a token (norm d) or zero; each expert section
    # holds at most C tokens by construction
    per_expert = np.asarray(jnp.abs(buf).sum(-1) > 0).sum(axis=1)
    assert (per_expert <= C).all()


# ---------------------------------------------------------------------------
# int8 compression error bounds
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 512), st.floats(1e-3, 1e3))
def test_compress_roundtrip_bound(n, scale):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n,), jnp.float32) * scale
    q, s = compress_int8(x)
    y = decompress_int8(q, s)
    # quantization error bounded by half a step
    assert float(jnp.abs(y - x).max()) <= float(s) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# capacity() is monotone and aligned
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096))
def test_capacity_aligned(T):
    class C:
        n_experts_per_tok = 2
        n_experts = 8
        capacity_factor = 1.25
    c = capacity(C, T)
    assert c % 8 == 0 and c >= 8
    assert c * C.n_experts >= T * C.n_experts_per_tok  # cap >= fair share


# ---------------------------------------------------------------------------
# flash attention == full attention over random shapes (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.integers(1, 2),                 # batch
       st.sampled_from([(2, 1), (4, 2), (6, 2), (4, 4)]),  # (hq, hkv)
       st.sampled_from([16, 24, 48, 64]),  # seq
       st.sampled_from([8, 16, 32]),       # head dim
       st.booleans())                      # causal
def test_flash_equals_full_attention(b, heads, s, d, causal):
    from repro.models.attention import attention_chunked, attention_full
    hq, hkv = heads
    key = jax.random.PRNGKey(b * 1000 + s + d)
    q = jax.random.normal(key, (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    pos = jnp.arange(s)
    out_c = attention_chunked(q, k, v, scale=d ** -0.5, causal=causal,
                              window=None, q_block=8, k_block=8)
    out_f = attention_full(q, k, v, scale=d ** -0.5, causal=causal,
                           window=None, q_pos=pos, k_pos=pos)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_f),
                               atol=5e-5)


# ---------------------------------------------------------------------------
# SSD chunked == sequential recurrence for any chunk size (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.sampled_from([8, 16, 32, 64]),   # seq
       st.sampled_from([4, 8, 16, 64]),    # chunk
       st.integers(1, 3))                  # heads
def test_ssd_chunked_matches_sequential(s, chunk, h):
    from repro.models.ssm import ssd_chunked
    from repro.kernels.ref import ssd_scan_ref
    b, p, n = 1, 8, 4
    key = jax.random.PRNGKey(s * 7 + chunk)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, n))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, h, n))
    y_c, h_c = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_r, h_r = ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# compressed_psum preserves the mean within quantization error (vmap)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(4, 64))
def test_compressed_psum_error_bound(n_ranks, width):
    from repro.optim import compressed_psum
    xs = jax.random.normal(jax.random.PRNGKey(n_ranks * 100 + width),
                           (n_ranks, width))

    def body(x, e):
        return compressed_psum(x, "dp", e)

    out, _ = jax.vmap(body, axis_name="dp")(xs, jnp.zeros_like(xs))
    ref = xs.mean(0)
    amax = float(jnp.abs(xs).max())
    # error <= half-step of the shared int8 grid
    assert float(jnp.abs(out[0] - ref).max()) <= amax / 127.0 + 1e-6
