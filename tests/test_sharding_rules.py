"""Logical-axis sharding rules: divisibility, axis dedup, overrides."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (DEFAULT_RULES, abstract_mesh, dp_axes,
                                     logical_spec, use_mesh)


def mesh2():
    return abstract_mesh((16, 16), ("data", "model"))


def mesh3():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_batch_takes_pod_and_data():
    spec = logical_spec(("batch", None, "embed"), (256, 4096, 896),
                        mesh3(), DEFAULT_RULES)
    assert spec[0] == ("pod", "data")
    # embed -> data is already used by batch -> dropped
    assert len(spec) < 3 or spec[2] is None


def test_divisibility_filter():
    # 14 q-heads cannot shard over model=16
    spec = logical_spec(("q_heads",), (14,), mesh2(),
                        {"q_heads": ("model",)})
    assert spec == P()
    spec2 = logical_spec(("q_heads",), (32,), mesh2(),
                         {"q_heads": ("model",)})
    assert spec2 == P("model")


def test_axis_prefix_partial():
    # batch 32 on (pod=2, data=16): 32 % 2 == 0, 32 % 32 == 0 -> both
    spec = logical_spec(("batch",), (32,), mesh3(), DEFAULT_RULES)
    assert spec == P(("pod", "data"))
    # batch 2 -> only pod fits
    spec2 = logical_spec(("batch",), (2,), mesh3(), DEFAULT_RULES)
    assert spec2 == P("pod")
    # batch 3 -> nothing fits
    spec3 = logical_spec(("batch",), (3,), mesh3(), DEFAULT_RULES)
    assert spec3 == P()


def test_param_fsdp_times_tp():
    # w [embed, mlp]: data x model fully sharded
    spec = logical_spec(("embed", "mlp"), (4096, 16384), mesh2(),
                        DEFAULT_RULES)
    assert spec == P("data", "model")


def test_expert_stack_sharding():
    spec = logical_spec(("experts", "embed", "moe_mlp"),
                        (256, 7168, 2048), mesh2(), DEFAULT_RULES)
    assert spec == P("model", "data")


def test_no_axis_reuse_within_tensor():
    spec = logical_spec(("seq", "vocab"), (4096, 151936), mesh2(),
                        DEFAULT_RULES)
    # both want model; seq wins (left-to-right), vocab drops
    assert spec == P("model")


def test_rule_override_via_use_mesh():
    m = mesh2()
    with use_mesh(None):
        pass
    from repro.parallel.sharding import active_rules, set_active_mesh
    with use_mesh(m, {"cache_seq": ("model",)}):
        assert active_rules()["cache_seq"] == ("model",)
        spec = logical_spec(("cache_batch", "cache_seq"), (32, 32768), m)
        assert spec == P("data", "model")
        # batch 8 cannot shard over data=16 -> dropped by divisibility
        spec8 = logical_spec(("cache_batch", "cache_seq"), (8, 32768), m)
        assert spec8 == P(None, "model")
    # restored afterwards
    assert active_rules().get("cache_seq") == ()


def test_dp_axes():
    assert dp_axes(mesh3()) == ("pod", "data")
    assert dp_axes(mesh2()) == ("data",)


def test_no_mesh_is_noop():
    import jax.numpy as jnp
    from repro.parallel.sharding import constrain, set_active_mesh
    set_active_mesh(None)
    x = jnp.ones((4, 4))
    assert constrain(x, ("batch", None)) is x


def test_pick_chunks_tp_aligned():
    from repro.models.attention import _pick_chunks
    # VLM seq 4096+576: must find a 16-multiple chunk count
    nq, bq = _pick_chunks(4672, 256, 16)
    assert nq % 16 == 0 and nq * bq == 4672
    # prefill 32768+576: falls to the 64-chunk divisor
    nq2, bq2 = _pick_chunks(33344, 256, 16)
    assert nq2 % 16 == 0 and nq2 * bq2 == 33344 and bq2 >= 64
    # power of two: exact
    assert _pick_chunks(4096, 256, 16) == (16, 256)
    # no tp-aligned divisor (prime seq): gcd fallback
    nq3, bq3 = _pick_chunks(97, 16, 16)
    assert nq3 * bq3 == 97


def test_resident_plan_budget():
    from repro.configs.base import get_config
    from repro.models.moe import resident_plan
    mesh = abstract_mesh((16, 16), ("data", "model"))
    # dsv3: 256 experts / 256 chips, small experts -> resident
    assert set(resident_plan(get_config("deepseek-v3-671b"), mesh)) == \
        {"data", "model"}
    # jamba: 16 fat experts -> over budget -> stream
    assert resident_plan(get_config("jamba-1.5-large-398b"), mesh) is None
    # dense arch: no experts
    assert resident_plan(get_config("qwen2-0.5b"), mesh) is None
