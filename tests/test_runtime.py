"""Trainer: convergence, checkpoint/restart, failure recovery,
straggler detection, gradient accumulation variants."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.checkpoint import (AsyncCheckpointer, latest_step, list_steps,
                              restore_checkpoint, save_checkpoint)
from repro.runtime import (FailureInjector, NodeFailure, StragglerMonitor,
                           TrainConfig, Trainer, elastic_reshard,
                           shrink_mesh_shape)


def tiny_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=211, dtype=jnp.float32,
                param_dtype=jnp.float32, remat="none")
    base.update(kw)
    return ModelConfig(**base)


def test_loss_decreases():
    tcfg = TrainConfig(lr=1e-3, warmup=5, total_steps=60, seq_len=32,
                       global_batch=8, log_every=5)
    tr = Trainer(tiny_cfg(), tcfg)
    tr.run(40)
    losses = [m["loss"] for m in tr.metrics_log]
    assert losses[-1] < losses[0]


def test_checkpoint_restart_resumes_exactly():
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(lr=1e-3, warmup=2, total_steps=30, seq_len=16,
                           global_batch=4, ckpt_dir=d, ckpt_every=5)
        tr = Trainer(tiny_cfg(), tcfg)
        tr.run(10)
        params_10 = jax.tree.map(np.asarray, tr.params)

        tr2 = Trainer(tiny_cfg(), tcfg)
        assert tr2.restore()
        assert tr2.step_count == 10
        for a, b in zip(jax.tree.leaves(params_10),
                        jax.tree.leaves(tr2.params)):
            np.testing.assert_array_equal(a, np.asarray(b))


def test_failure_recovery_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(lr=1e-3, warmup=2, total_steps=40, seq_len=16,
                           global_batch=4, ckpt_dir=d, ckpt_every=5)
        inj = FailureInjector(fail_at=[7, 13])
        tr = Trainer(tiny_cfg(), tcfg, failure_injector=inj)
        out = tr.run(20)
        assert out["failures"] == 2
        assert out["final_step"] == 20
        assert inj.fired == [7, 13]


def test_failure_before_checkpoint_raises():
    tcfg = TrainConfig(lr=1e-3, total_steps=10, seq_len=16,
                       global_batch=4, ckpt_dir=None)
    inj = FailureInjector(fail_at=[2])
    tr = Trainer(tiny_cfg(), tcfg, failure_injector=inj)
    with pytest.raises((RuntimeError, NodeFailure)):
        tr.run(5)


def test_grad_accum_equivalence():
    """grad_accum=2 on batch 8 == one step on the same data."""
    t1 = TrainConfig(lr=1e-3, warmup=0, total_steps=5, seq_len=16,
                     global_batch=8, grad_accum=1, donate=False)
    t2 = TrainConfig(lr=1e-3, warmup=0, total_steps=5, seq_len=16,
                     global_batch=8, grad_accum=2, donate=False)
    tr1, tr2 = Trainer(tiny_cfg(), t1), Trainer(tiny_cfg(), t2)
    tr1._run_until(1)
    tr2._run_until(1)
    for a, b in zip(jax.tree.leaves(tr1.params),
                    jax.tree.leaves(tr2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5)


def test_compressed_accum_close_to_exact():
    t2 = TrainConfig(lr=1e-3, warmup=0, total_steps=5, seq_len=16,
                     global_batch=8, grad_accum=2, compressed_accum=True,
                     donate=False)
    t1 = TrainConfig(lr=1e-3, warmup=0, total_steps=5, seq_len=16,
                     global_batch=8, grad_accum=2, donate=False)
    tr1, tr2 = Trainer(tiny_cfg(), t1), Trainer(tiny_cfg(), t2)
    tr1._run_until(1)
    tr2._run_until(1)
    ref = np.concatenate([np.asarray(x).ravel()
                          for x in jax.tree.leaves(tr1.params)])
    got = np.concatenate([np.asarray(x).ravel()
                          for x in jax.tree.leaves(tr2.params)])
    # int8 error-feedback accumulator reconstructs the sum exactly
    # (residual carried in f32), so parameters match tightly
    np.testing.assert_allclose(got, ref, atol=5e-5)


# -- straggler monitor --------------------------------------------------------
def test_straggler_monitor_flags_and_recommends_remesh():
    mon = StragglerMonitor(threshold=2.0, patience=2)
    assert mon.observe(1, 1.0) == "ok"
    assert mon.observe(2, 1.05) == "ok"
    assert mon.observe(3, 5.0) == "slow"
    assert mon.observe(4, 5.0) == "remesh"
    # healthy steps reset the streak
    assert mon.observe(5, 1.0) == "ok"
    assert mon.observe(6, 5.0) == "slow"
    assert mon.observe(7, 1.0) == "ok"
    assert len(mon.events) == 3


def test_straggler_monitor_ema_freeze_on_slow_streak():
    """Slow steps must not poison the EMA baseline — only healthy
    steps fold in, so a persistent straggler is still detected against
    the pre-slowdown baseline."""
    mon = StragglerMonitor(threshold=2.0, patience=3, ema_decay=0.5)
    mon.observe(0, 1.0)
    ema0 = mon.ema
    assert mon.observe(1, 10.0) == "slow"
    assert mon.observe(2, 10.0) == "slow"
    assert mon.ema == ema0               # frozen during the streak
    assert mon.observe(3, 10.0) == "remesh"
    assert mon.slow_streak == 0          # reset after the recommendation
    assert mon.ema == ema0
    mon.observe(4, 1.2)                  # healthy step updates the EMA
    assert mon.ema == pytest.approx(0.5 * ema0 + 0.5 * 1.2)


def test_elastic_reshard_round_trip():
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev)
    tree = {"w": jnp.arange(8.0).reshape(2, 4), "b": jnp.ones((3,))}
    out = elastic_reshard(tree, {"w": sh, "b": sh})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.asarray(tree["b"]))
    assert out["w"].sharding.is_equivalent_to(sh, out["w"].ndim)


def test_shrink_mesh_shape():
    # each halving of data=16 removes data/2 * model = 8*16 = 128
    # actual devices — one halving covers any small loss (the old
    # `covered*2+1` accounting over-shrunk lost=3 to data=4)
    for lost in (1, 2, 5):
        assert shrink_mesh_shape({"data": 16, "model": 16}, lost=lost) \
            == {"data": 8, "model": 16}
    # without a model axis the per-halving coverage is data/2
    assert shrink_mesh_shape({"data": 8}, lost=1) == {"data": 4}
    assert shrink_mesh_shape({"data": 8}, lost=2) == {"data": 4}
    assert shrink_mesh_shape({"data": 8}, lost=5) == {"data": 2}
    # "all lost": halve until the data axis is exhausted
    assert shrink_mesh_shape({"data": 8}, lost=8) == {"data": 1}
    assert shrink_mesh_shape({"data": 16, "model": 16}, lost=256) == \
        {"data": 1, "model": 16}
    # any loss forces at least one halving; data=1 cannot shrink
    assert shrink_mesh_shape({"data": 16, "model": 16}, lost=0) == \
        {"data": 8, "model": 16}
    assert shrink_mesh_shape({"data": 1, "model": 16}, lost=2) == \
        {"data": 1, "model": 16}


# -- checkpoint store ---------------------------------------------------------
def test_checkpoint_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 3))}}
        for step in (1, 2, 3, 4):
            save_checkpoint(d, step, jax.tree.map(lambda x: x * step,
                                                  tree))
        # a stale .tmp dir must be ignored
        os.makedirs(os.path.join(d, "step_000000099.tmp"))
        assert list_steps(d) == [1, 2, 3, 4]
        assert latest_step(d) == 4
        restored, step, _ = restore_checkpoint(d, tree)
        assert step == 4
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.arange(4.0) * 4)

        ck = AsyncCheckpointer(d, keep=2)
        ck.save(5, tree)
        ck.wait()
        assert list_steps(d) == [4, 5] or list_steps(d) == [3, 4, 5][-2:]


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            restore_checkpoint(d, {"a": jnp.zeros((5,))})


def test_checkpoint_missing_leaf_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.zeros(2)})
        with pytest.raises(KeyError):
            restore_checkpoint(d, {"a": jnp.zeros(2), "b": jnp.zeros(2)})
