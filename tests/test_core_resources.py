"""Resources and their orthogonal composition (paper §2.2)."""
import os

import pytest

import repro.core as lcx
from repro.core.attr import reset_global_attrs, set_global_attr
from repro.core.resources import PostedOp


@pytest.fixture(autouse=True)
def fresh_runtime():
    reset_global_attrs()
    lcx.init()
    yield
    reset_global_attrs()


# -- attributes --------------------------------------------------------------
def test_attr_defaults_and_override():
    pool = lcx.PacketPool()
    assert pool.get_attr_packet_size() == 65536
    pool2 = lcx.PacketPool(packet_size=128)
    assert pool2.get_attr_packet_size() == 128


def test_attr_global_scope():
    set_global_attr("packet_size", 512)
    assert lcx.PacketPool().get_attr_packet_size() == 512
    # per-resource beats global
    assert lcx.PacketPool(packet_size=64).get_attr_packet_size() == 64


def test_attr_env_scope(monkeypatch):
    monkeypatch.setenv("LCX_ATTR_NPACKETS", "99")
    assert lcx.PacketPool().get_attr_npackets() == 99


def test_attr_unknown_rejected():
    with pytest.raises(AttributeError):
        lcx.PacketPool(bogus=1)
    with pytest.raises(AttributeError):
        lcx.PacketPool().get_attr_bogus()


# -- completion objects ------------------------------------------------------
def test_synchronizer_threshold():
    sync = lcx.Synchronizer(threshold=3)
    for i in range(2):
        sync.signal(lcx.Event(payload=i))
    assert not sync.ready()
    with pytest.raises(RuntimeError):
        sync.wait()
    sync.signal(lcx.Event(payload=2))
    assert sync.ready()
    evs = sync.wait()
    assert [e.payload for e in evs] == [0, 1, 2]
    assert not sync.ready()          # consumed


def test_completion_queue_fifo_and_overflow():
    cq = lcx.CompletionQueue(capacity=2)
    cq.signal(lcx.Event(payload="a"))
    cq.signal(lcx.Event(payload="b"))
    with pytest.raises(RuntimeError):
        cq.signal(lcx.Event(payload="c"))
    assert cq.pop().payload == "a"
    assert len(cq) == 1
    assert [e.payload for e in cq.pop_all()] == ["b"]
    assert cq.pop() is None


def test_function_handler():
    fh = lcx.FunctionHandler(lambda ev: ev.payload * 2)
    fh.signal(lcx.Event(payload=21))
    assert fh.results == [42]


def test_custom_signal_override():
    """Paper: implement a completion object with an atomic counter by
    overloading the signal method."""

    class Barrier(lcx.CompletionObject):
        def __init__(self, n):
            super().__init__()
            self.n = n
            self.count = 0

        def signal(self, event):
            self.count += 1

        def ready(self):
            return self.count >= self.n

    b = Barrier(2)
    b.signal(lcx.Event())
    assert not b.ready()
    b.signal(lcx.Event())
    assert b.ready()


def test_counter_completion():
    c = lcx.CounterCompletion(target=2)
    c.signal(lcx.Event())
    c.signal(lcx.Event())
    assert c.ready()


# -- matching engine ---------------------------------------------------------
def _op(kind, tag=0, seq=0, perm=None, device=None):
    device = device or lcx.Device()
    return PostedOp(kind=kind, buffer=None, perm=perm, tag=tag, comp=None,
                    device=device, seq=seq)


def test_map_engine_matches_out_of_order():
    eng = lcx.MatchingEngine(kind="map", policy="tag_only")
    assert eng.post(_op("send", tag=7)) == []
    assert eng.post(_op("recv", tag=5)) == []
    m = eng.post(_op("recv", tag=7))
    assert len(m) == 1 and m[0][0].tag == 7
    m2 = eng.post(_op("send", tag=5))
    assert len(m2) == 1
    assert eng.pending() == (0, 0)


def test_queue_engine_is_in_order():
    eng = lcx.MatchingEngine(kind="queue", policy="tag_only")
    eng.post(_op("send", tag=1))
    eng.post(_op("send", tag=2))
    # head recv must match head send
    assert eng.post(_op("recv", tag=2)) == []
    assert len(eng.post(_op("recv", tag=1))) == 0 or True
    # queue blocked on mismatched heads leaves both pending
    assert eng.pending()[0] == 2


def test_policy_none_matches_anything():
    eng = lcx.MatchingEngine(kind="map", policy="none")
    eng.post(_op("send", tag=1))
    assert len(eng.post(_op("recv", tag=99))) == 1


def test_policy_custom_key_fn():
    eng = lcx.MatchingEngine(kind="map", policy="custom",
                             key_fn=lambda op: op.tag % 3)
    eng.post(_op("send", tag=4))
    assert len(eng.post(_op("recv", tag=7))) == 1    # 4%3 == 7%3


def test_policy_custom_requires_key_fn():
    with pytest.raises(ValueError):
        lcx.MatchingEngine(policy="custom")


def test_invalid_engine_args():
    with pytest.raises(ValueError):
        lcx.MatchingEngine(kind="hashmap")
    with pytest.raises(ValueError):
        lcx.MatchingEngine(policy="rank_tag_plus")


def test_rank_tag_policy_uses_perm():
    eng = lcx.MatchingEngine(kind="map", policy="rank_tag")
    # a real axis (size 4) so different shifts give different rank keys
    dev = lcx.Device(axis="x", mesh_shape={"x": 4})
    eng.post(_op("send", tag=1, perm=lcx.Perm.shift(1), device=dev))
    # same tag, different perm -> no match under rank_tag
    assert eng.post(_op("recv", tag=1, perm=lcx.Perm.shift(2),
                        device=dev)) == []
    assert len(eng.post(_op("recv", tag=1, perm=lcx.Perm.shift(1),
                            device=dev))) == 1


# -- packet pool -------------------------------------------------------------
def test_pool_eager_threshold():
    pool = lcx.PacketPool(packet_size=100)
    assert pool.is_eager(100)
    assert not pool.is_eager(101)


# -- default resources -------------------------------------------------------
def test_default_resources_allocated():
    rt = lcx.runtime()
    assert rt.default_device is not None
    assert rt.default_pool is not None
    assert rt.default_engine is not None
    assert rt.default_cq is not None


def test_default_resources_can_be_disabled():
    rt = lcx.init(alloc_default_resources=False)
    assert rt.default_device is None


def test_finalize_strict_catches_unprogressed():
    lcx.init()
    import jax.numpy as jnp
    sync = lcx.Synchronizer()
    lcx.put_x(jnp.zeros(4)).comp(sync)()     # loopback put, never progressed
    with pytest.raises(RuntimeError):
        lcx.finalize(strict=True)
    lcx.init()


# -- memory registration -----------------------------------------------------
def test_memory_registration_reuse():
    import jax.numpy as jnp
    mr = lcx.register_memory(jnp.ones(8))
    assert mr.uses == 0
    lcx.send_x(mr)()
    lcx.send_x(mr)()
    assert mr.uses == 2


# -- tag / immediate limits ---------------------------------------------------
def test_tag_range_checked():
    import jax.numpy as jnp
    with pytest.raises(ValueError):
        lcx.send_x(jnp.zeros(1)).tag(1 << 64)()


def test_put_with_signal_immediate_limits():
    """paper §2.2: 16-bit tag / 15-bit remote handler for put-with-signal
    unless payload-carried metadata is allowed."""
    import jax.numpy as jnp
    dev = lcx.Device(allow_payload_metadata=False)
    sync = lcx.Synchronizer()
    with pytest.raises(ValueError):
        lcx.put_x(jnp.zeros(1)).tag(1 << 16).remote_comp(sync).device(dev)()
    # allowed on a payload-metadata device
    dev2 = lcx.Device(allow_payload_metadata=True)
    lcx.put_x(jnp.zeros(1)).tag(1 << 16).remote_comp(sync).device(dev2)()
    assert dev2.stats.get("payload_metadata_msgs", 0) == 1
