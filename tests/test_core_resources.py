"""Resources and their orthogonal composition (paper §2.2)."""
import os

import pytest

import repro.core as lcx
from repro.core.attr import reset_global_attrs, set_global_attr
from repro.core.resources import PostedOp


@pytest.fixture(autouse=True)
def fresh_runtime():
    reset_global_attrs()
    lcx.init()
    yield
    reset_global_attrs()


# -- attributes --------------------------------------------------------------
def test_attr_defaults_and_override():
    pool = lcx.PacketPool()
    assert pool.get_attr_packet_size() == 65536
    pool2 = lcx.PacketPool(packet_size=128)
    assert pool2.get_attr_packet_size() == 128


def test_attr_global_scope():
    set_global_attr("packet_size", 512)
    assert lcx.PacketPool().get_attr_packet_size() == 512
    # per-resource beats global
    assert lcx.PacketPool(packet_size=64).get_attr_packet_size() == 64


def test_attr_env_scope(monkeypatch):
    monkeypatch.setenv("LCX_ATTR_NPACKETS", "99")
    assert lcx.PacketPool().get_attr_npackets() == 99


def test_attr_unknown_rejected():
    with pytest.raises(AttributeError):
        lcx.PacketPool(bogus=1)
    with pytest.raises(AttributeError):
        lcx.PacketPool().get_attr_bogus()


# -- completion objects ------------------------------------------------------
def test_synchronizer_threshold():
    sync = lcx.Synchronizer(threshold=3)
    for i in range(2):
        sync.signal(lcx.Event(payload=i))
    assert not sync.ready()
    with pytest.raises(RuntimeError):
        sync.wait()
    sync.signal(lcx.Event(payload=2))
    assert sync.ready()
    evs = sync.wait()
    assert [e.payload for e in evs] == [0, 1, 2]
    assert not sync.ready()          # consumed


def test_completion_queue_fifo_and_overflow():
    cq = lcx.CompletionQueue(capacity=2)
    assert cq.signal(lcx.Event(payload="a")) is lcx.ErrorCode.OK
    assert cq.signal(lcx.Event(payload="b")) is lcx.ErrorCode.OK
    # overflow is backpressure, not a crash: the event is refused with
    # a retry status (LCI's posts-return-retry idiom), never enqueued
    assert cq.signal(lcx.Event(payload="c")) is lcx.ErrorCode.RETRY
    assert cq.overflows == 1
    assert cq.pop().payload == "a"
    assert len(cq) == 1
    assert [e.payload for e in cq.pop_all()] == ["b"]
    assert cq.pop() is None


def test_function_handler():
    fh = lcx.FunctionHandler(lambda ev: ev.payload * 2)
    fh.signal(lcx.Event(payload=21))
    assert fh.results == [42]


def test_custom_signal_override():
    """Paper: implement a completion object with an atomic counter by
    overloading the signal method."""

    class Barrier(lcx.CompletionObject):
        def __init__(self, n):
            super().__init__()
            self.n = n
            self.count = 0

        def signal(self, event):
            self.count += 1

        def ready(self):
            return self.count >= self.n

    b = Barrier(2)
    b.signal(lcx.Event())
    assert not b.ready()
    b.signal(lcx.Event())
    assert b.ready()


def test_counter_completion():
    c = lcx.CounterCompletion(target=2)
    c.signal(lcx.Event())
    c.signal(lcx.Event())
    assert c.ready()


# -- matching engine ---------------------------------------------------------
def _op(kind, tag=0, seq=0, perm=None, device=None):
    device = device or lcx.Device()
    return PostedOp(kind=kind, buffer=None, perm=perm, tag=tag, comp=None,
                    device=device, seq=seq)


def test_map_engine_matches_out_of_order():
    eng = lcx.MatchingEngine(kind="map", policy="tag_only")
    assert eng.post(_op("send", tag=7)) == []
    assert eng.post(_op("recv", tag=5)) == []
    m = eng.post(_op("recv", tag=7))
    assert len(m) == 1 and m[0][0].tag == 7
    m2 = eng.post(_op("send", tag=5))
    assert len(m2) == 1
    assert eng.pending() == (0, 0)


def test_queue_engine_is_in_order():
    eng = lcx.MatchingEngine(kind="queue", policy="tag_only")
    eng.post(_op("send", tag=1))
    eng.post(_op("send", tag=2))
    # head recv must match head send
    assert eng.post(_op("recv", tag=2)) == []
    assert len(eng.post(_op("recv", tag=1))) == 0 or True
    # queue blocked on mismatched heads leaves both pending
    assert eng.pending()[0] == 2


def test_policy_none_matches_anything():
    eng = lcx.MatchingEngine(kind="map", policy="none")
    eng.post(_op("send", tag=1))
    assert len(eng.post(_op("recv", tag=99))) == 1


def test_policy_custom_key_fn():
    eng = lcx.MatchingEngine(kind="map", policy="custom",
                             key_fn=lambda op: op.tag % 3)
    eng.post(_op("send", tag=4))
    assert len(eng.post(_op("recv", tag=7))) == 1    # 4%3 == 7%3


def test_policy_custom_requires_key_fn():
    with pytest.raises(ValueError):
        lcx.MatchingEngine(policy="custom")


def test_invalid_engine_args():
    with pytest.raises(ValueError):
        lcx.MatchingEngine(kind="hashmap")
    with pytest.raises(ValueError):
        lcx.MatchingEngine(policy="rank_tag_plus")


def test_rank_tag_policy_uses_perm():
    eng = lcx.MatchingEngine(kind="map", policy="rank_tag")
    # a real axis (size 4) so different shifts give different rank keys
    dev = lcx.Device(axis="x", mesh_shape={"x": 4})
    eng.post(_op("send", tag=1, perm=lcx.Perm.shift(1), device=dev))
    # same tag, different perm -> no match under rank_tag
    assert eng.post(_op("recv", tag=1, perm=lcx.Perm.shift(2),
                        device=dev)) == []
    assert len(eng.post(_op("recv", tag=1, perm=lcx.Perm.shift(1),
                            device=dev))) == 1


# -- keyed fast path vs reference scan (regression for the O(1) rewrite) -----
class _RefScanEngine:
    """The pre-optimization O(S×R) matching semantics, kept as the test
    oracle: one pending list per side, full rescan after every post."""

    def __init__(self, kind, policy, key_fn=None):
        self.kind, self.policy, self.key_fn = kind, policy, key_fn
        self.sends, self.recvs = [], []

    def _key(self, op):
        if self.policy == "none":
            return ()
        if self.policy == "rank_only":
            return op.perm.key(op.device.axis_size) if op.perm else ()
        if self.policy == "tag_only":
            return op.tag
        if self.policy == "rank_tag":
            return ((op.perm.key(op.device.axis_size) if op.perm else ()),
                    op.tag)
        return self.key_fn(op)

    def post(self, op):
        (self.sends if op.kind == "send" else self.recvs).append(op)
        matches = []
        if self.kind == "queue":
            while self.sends and self.recvs:
                s, r = self.sends[0], self.recvs[0]
                if self._key(s) != self._key(r):
                    break
                matches.append((self.sends.pop(0), self.recvs.pop(0)))
            return matches
        changed = True
        while changed:
            changed = False
            for s in list(self.sends):
                ks = self._key(s)
                for r in list(self.recvs):
                    if ks == self._key(r):
                        self.sends.remove(s)
                        self.recvs.remove(r)
                        matches.append((s, r))
                        changed = True
                        break
                if changed:
                    break
        return matches


def _random_op_stream(rng, n, device):
    perms = [None, lcx.Perm.shift(1), lcx.Perm.shift(2),
             lcx.Perm.pairs([(0, 1)]),
             lcx.Perm.pairs([(1, 2), (0, 1)])]
    ops = []
    for seq in range(n):
        ops.append(PostedOp(
            kind=rng.choice(("send", "recv")), buffer=None,
            perm=rng.choice(perms), tag=rng.randrange(4), comp=None,
            device=device, seq=seq))
    return ops


@pytest.mark.parametrize("kind", ["map", "queue"])
@pytest.mark.parametrize("policy", ["none", "rank_only", "tag_only",
                                    "rank_tag", "custom"])
def test_keyed_matching_identical_to_reference_scan(kind, policy):
    """The hash-bucket fast path must reproduce the old scan's pairings
    and match orderings exactly, for every kind x policy."""
    import random
    key_fn = (lambda op: op.tag % 3) if policy == "custom" else None
    rng = random.Random(f"{kind}/{policy}")
    dev = lcx.Device(axis="x", mesh_shape={"x": 4})
    ops = _random_op_stream(rng, 400, dev)
    ref = _RefScanEngine(kind, policy, key_fn)
    eng = lcx.MatchingEngine(kind=kind, policy=policy, key_fn=key_fn)
    for op in ops:
        ref_matches = [(s.seq, r.seq) for s, r in ref.post(op)]
        got = [(s.seq, r.seq) for s, r in eng.post(op)]
        assert got == ref_matches, (kind, policy, op.seq)
    assert eng.pending() == (len(ref.sends), len(ref.recvs))


def test_map_engine_unhashable_custom_keys():
    """Custom key_fns returning unhashable keys fall back to the linear
    overflow path with the same oldest-first semantics."""
    eng = lcx.MatchingEngine(kind="map", policy="custom",
                             key_fn=lambda op: [op.tag % 2])
    eng.post(_op("send", tag=0, seq=0))
    eng.post(_op("send", tag=2, seq=1))
    assert eng.pending() == (2, 0)
    m = eng.post(_op("recv", tag=4, seq=2))
    # matches the OLDEST pending send with an equal key
    assert len(m) == 1 and m[0][0].seq == 0
    m2 = eng.post(_op("recv", tag=6, seq=3))
    assert len(m2) == 1 and m2[0][0].seq == 1
    assert eng.pending() == (0, 0)


def test_match_key_computed_once_per_op():
    calls = []

    def key_fn(op):
        calls.append(op.seq)
        return op.tag

    eng = lcx.MatchingEngine(kind="map", policy="custom", key_fn=key_fn)
    for i in range(8):
        eng.post(_op("send", tag=i, seq=i))
    for i in range(8):
        eng.post(_op("recv", tag=i, seq=8 + i))
    # one key derivation per posted op — never recomputed in a drain loop
    assert len(calls) == 16


def test_perm_key_memoized_per_axis_size():
    calls = []
    p = lcx.Perm(lambda n: calls.append(n) or [(i, (i + 1) % n)
                                               for i in range(n)], "probe")
    assert p.key(4) == p.key(4) and len(calls) == 1
    p.key(8)
    assert len(calls) == 2
    assert p.pairs_for(4) is p.pairs_for(4)     # memoized list reused


# -- per-device transfer ledgers ---------------------------------------------
def test_take_ready_device_isolation_two_devices_one_axis():
    """Two devices on one axis progress independently: draining one
    device's ledger must not disturb the other's (LCI device-per-thread
    isolation)."""
    rt = lcx.runtime()
    d1 = lcx.Device(axis="x", mesh_shape={"x": 4})
    d2 = lcx.Device(axis="x", mesh_shape={"x": 4})
    m1 = (_op("send", tag=1, device=d1), _op("recv", tag=1, device=d1))
    m2 = (_op("send", tag=2, device=d2), _op("recv", tag=2, device=d2))
    m3 = (_op("send", tag=3, device=d1), _op("recv", tag=3, device=d1))
    rt.enqueue_matches([m1, m2, m3])
    assert rt.pending_count() == 3
    got1 = rt.take_ready(d1)
    assert got1 == [m1, m3]
    assert rt.pending_count() == 1
    # d2's traffic untouched; a second drain of d1 is empty
    assert rt.take_ready(d1) == []
    assert rt.take_ready(d2) == [m2]
    assert rt.pending_count() == 0


def test_take_ready_cross_device_match_claimed_once():
    """A match whose send and recv sit on different devices (shared
    engine) is claimed by whichever device drains first — and only once."""
    rt = lcx.runtime()
    d1, d2 = lcx.Device(), lcx.Device()
    m = (_op("send", tag=1, device=d1), _op("recv", tag=1, device=d2))
    rt.enqueue_matches([m])
    assert rt.pending_count() == 1
    assert rt.take_ready(d1) == [m]
    assert rt.take_ready(d2) == []
    assert rt.pending_count() == 0
    # drain-all also sees each match exactly once
    rt.enqueue_matches([m])
    assert rt.take_ready() == [m]
    assert rt.take_ready() == []
    assert rt.pending_count() == 0


# -- packet pool -------------------------------------------------------------
def test_pool_eager_threshold():
    pool = lcx.PacketPool(packet_size=100)
    assert pool.is_eager(100)
    assert not pool.is_eager(101)


# -- default resources -------------------------------------------------------
def test_default_resources_allocated():
    rt = lcx.runtime()
    assert rt.default_device is not None
    assert rt.default_pool is not None
    assert rt.default_engine is not None
    assert rt.default_cq is not None


def test_default_resources_can_be_disabled():
    rt = lcx.init(alloc_default_resources=False)
    assert rt.default_device is None


def test_finalize_strict_catches_unprogressed():
    lcx.init()
    import jax.numpy as jnp
    sync = lcx.Synchronizer()
    lcx.put_x(jnp.zeros(4)).comp(sync)()     # loopback put, never progressed
    with pytest.raises(RuntimeError):
        lcx.finalize(strict=True)
    lcx.init()


# -- memory registration -----------------------------------------------------
def test_memory_registration_reuse():
    import jax.numpy as jnp
    mr = lcx.register_memory(jnp.ones(8))
    assert mr.uses == 0
    lcx.send_x(mr)()
    lcx.send_x(mr)()
    assert mr.uses == 2


# -- tag / immediate limits ---------------------------------------------------
def test_tag_range_checked():
    import jax.numpy as jnp
    with pytest.raises(ValueError):
        lcx.send_x(jnp.zeros(1)).tag(1 << 64)()


def test_put_with_signal_immediate_limits():
    """paper §2.2: 16-bit tag / 15-bit remote handler for put-with-signal
    unless payload-carried metadata is allowed."""
    import jax.numpy as jnp
    dev = lcx.Device(allow_payload_metadata=False)
    sync = lcx.Synchronizer()
    with pytest.raises(ValueError):
        lcx.put_x(jnp.zeros(1)).tag(1 << 16).remote_comp(sync).device(dev)()
    # allowed on a payload-metadata device
    dev2 = lcx.Device(allow_payload_metadata=True)
    lcx.put_x(jnp.zeros(1)).tag(1 << 16).remote_comp(sync).device(dev2)()
    assert dev2.stats.get("payload_metadata_msgs", 0) == 1
