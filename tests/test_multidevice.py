"""Multi-device integration tests.

These need >1 XLA device, so each runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process keeps the default single device, per the project rule that only
the dry-run sees placeholder devices).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_moe_ep_lcx_matches_local_oracle():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig
        from repro.models import init_model, apply_model
        from repro.parallel.sharding import use_mesh, param_shardings
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        f32 = dict(dtype=jnp.float32, param_dtype=jnp.float32, q_block=8)
        cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab=97,
                          n_experts=8, n_experts_per_tok=2, moe_d_ff=96,
                          moe_backend="lcx", capacity_factor=16.0, **f32)
        ref_cfg = dataclasses.replace(cfg, moe_backend="sort")
        params, dims = init_model(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 97)
        ref, _ = apply_model(ref_cfg, params, toks)
        with use_mesh(mesh):
            ps = param_shardings(dims, params, mesh)
            params_s = jax.device_put(params, ps)
            toks_s = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
            out, _ = jax.jit(lambda p, t: apply_model(cfg, p, t))(params_s, toks_s)
        err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
        assert err < 5e-5, err
        print("ok", err)
        """)


def test_ring_allgather_pallas_kernel():
    # Pinned-jax note: interpret mode needs pltpu.InterpretParams and
    # pltpu.sync_copy, which only exist on newer JAX releases; on this
    # pin the kernel is TPU-hardware-only.
    from repro.kernels.ring_allgather import tpu_interpret_available
    if not tpu_interpret_available():
        pytest.skip("pinned JAX lacks pltpu TPU interpret machinery "
                    "(InterpretParams/sync_copy)")
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.kernels.ring_allgather import ring_all_gather
        from repro.compat import make_mesh, shard_map
        mesh = make_mesh((8,), ("x",))
        x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
        f = shard_map(lambda s: ring_all_gather(s, "x", axis_size=8),
                      mesh, in_specs=P("x", None),
                      out_specs=P("x", None))
        out = jax.jit(f)(x)
        got = np.asarray(out).reshape(8, 8, 16)
        assert (got == np.asarray(x)[None]).all()
        print("ok")
        """)


def test_train_step_sharded_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.runtime import Trainer, TrainConfig
        from repro.compat import make_mesh
        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab=211,
                          dtype=jnp.float32, param_dtype=jnp.float32,
                          remat="none", q_block=8)
        tcfg = TrainConfig(lr=1e-3, warmup=0, total_steps=4, seq_len=32,
                           global_batch=8, donate=False)
        mesh = make_mesh((2, 4), ("data", "model"))
        tr_m = Trainer(cfg, tcfg, mesh=mesh)
        tr_1 = Trainer(cfg, tcfg, mesh=None)
        tr_m._run_until(2)
        tr_1._run_until(2)
        a = np.concatenate([np.asarray(x).ravel()
                            for x in jax.tree.leaves(tr_m.params)])
        b = np.concatenate([np.asarray(x).ravel()
                            for x in jax.tree.leaves(tr_1.params)])
        err = np.abs(a - b).max()
        assert err < 2e-4, err
        print("ok", err)
        """)


def test_elastic_remesh_preserves_state():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.runtime import Trainer, TrainConfig
        from repro.compat import make_mesh
        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab=211,
                          dtype=jnp.float32, param_dtype=jnp.float32,
                          remat="none", q_block=8)
        tcfg = TrainConfig(lr=1e-3, warmup=0, total_steps=8, seq_len=32,
                           global_batch=8, donate=False)
        mesh8 = make_mesh((4, 2), ("data", "model"))
        mesh4 = make_mesh((2, 2), ("data", "model"))
        tr = Trainer(cfg, tcfg, mesh=mesh8)
        tr._run_until(2)
        before = np.concatenate([np.asarray(x).ravel()
                                 for x in jax.tree.leaves(tr.params)])
        # simulate losing half the data-parallel hosts
        tr.remesh(mesh4)
        after = np.concatenate([np.asarray(x).ravel()
                                for x in jax.tree.leaves(tr.params)])
        np.testing.assert_array_equal(before, after)
        tr._run_until(4)   # keeps training on the shrunken mesh
        assert tr.step_count == 4
        print("ok")
        """)


def test_seq_sharded_decode_paths():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig
        from repro.models import (init_model, init_cache, prefill,
                                  decode_step)
        from repro.parallel.sharding import use_mesh, param_shardings
        from repro.compat import make_mesh
        from repro.launch.steps import cache_dims, decode_rules
        mesh = make_mesh((2, 4), ("data", "model"))
        f32 = dict(dtype=jnp.float32, param_dtype=jnp.float32, q_block=8)
        cfg = ModelConfig(name="g", n_layers=2, d_model=64, n_heads=6,
                          n_kv_heads=2, d_ff=128, vocab=97, **f32)
        params, dims = init_model(jax.random.PRNGKey(0), cfg)
        B, S, SMAX = 4, 16, 32
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 97)
        caches = init_cache(cfg, B, SMAX)
        lg, caches = prefill(cfg, params, toks, caches)
        nxt = jnp.argmax(lg[:, -1], -1)[:, None]
        ref, _ = decode_step(cfg, params, nxt, caches, jnp.int32(S))
        rules = decode_rules(cfg, mesh)
        with use_mesh(mesh, rules):
            ps = param_shardings(dims, params, mesh)
            cproto = jax.eval_shape(lambda: init_cache(cfg, B, SMAX))
            cs = param_shardings(cache_dims(cfg, cproto), cproto, mesh)
            step = jax.jit(lambda p, t, c, l: decode_step(cfg, p, t, c, l),
                           in_shardings=(ps, NamedSharding(mesh, P("data", None)),
                                         cs, NamedSharding(mesh, P())),
                           out_shardings=(None, cs))
            got, _ = step(jax.device_put(params, ps),
                          jax.device_put(nxt, NamedSharding(mesh, P("data", None))),
                          jax.device_put(caches, cs), jnp.int32(S))
        err = float(np.abs(np.asarray(got) - np.asarray(ref)).max())
        assert err < 1e-4, err
        print("ok", err)
        """)


def test_resident_expert_decode_matches_oracle():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig
        from repro.models import init_model, init_cache, prefill, decode_step
        from repro.parallel.sharding import use_mesh, param_shardings
        from repro.compat import make_mesh
        from repro.launch.steps import cache_dims, decode_rules
        mesh = make_mesh((2, 4), ("data", "model"))
        f32 = dict(dtype=jnp.float32, param_dtype=jnp.float32, q_block=8)
        cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab=97,
                          n_experts=8, n_experts_per_tok=2, moe_d_ff=96,
                          moe_backend="lcx", capacity_factor=8.0,
                          n_shared_experts=1, **f32)
        params, dims = init_model(jax.random.PRNGKey(0), cfg)
        B, S, SMAX = 4, 16, 32
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 97)
        ref_cfg = dataclasses.replace(cfg, moe_backend="sort")
        caches = init_cache(cfg, B, SMAX)
        lg, caches2 = prefill(ref_cfg, params, toks, caches)
        nxt = jnp.argmax(lg[:, -1], -1)[:, None]
        ref, _ = decode_step(ref_cfg, params, nxt, caches2, jnp.int32(S))
        rules = decode_rules(cfg, mesh)
        assert set(rules.get("experts", ())) == {"data", "model"}, rules
        with use_mesh(mesh, rules):
            psh = param_shardings(dims, params, mesh)
            cproto = jax.eval_shape(lambda: init_cache(cfg, B, SMAX))
            csh = param_shardings(cache_dims(cfg, cproto), cproto, mesh)
            step = jax.jit(lambda p, t, c, l: decode_step(cfg, p, t, c, l),
                           in_shardings=(psh, NamedSharding(mesh, P("data", None)),
                                         csh, NamedSharding(mesh, P())),
                           out_shardings=(None, csh))
            got, _ = step(jax.device_put(params, psh),
                          jax.device_put(nxt, NamedSharding(mesh, P("data", None))),
                          jax.device_put(caches2, csh), jnp.int32(S))
        err = float(np.abs(np.asarray(got) - np.asarray(ref)).max())
        assert err < 1e-4, err
        print("ok", err)
        """)


def test_pipeline_parallel_forward_and_grads():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.models import init_model, apply_model, loss_fn
        from repro.parallel.pp import pp_apply_model, pp_loss
        from repro.parallel.sharding import use_mesh
        from repro.compat import make_mesh
        mesh = make_mesh((4, 2), ("pipe", "data"))
        cfg = ModelConfig(name="pp", n_layers=8, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab=97,
                          dtype=jnp.float32, param_dtype=jnp.float32,
                          q_block=8, remat="none")
        params, _ = init_model(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 97)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        ref, _ = apply_model(cfg, params, toks)
        ref_grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
        with use_mesh(mesh):
            out = jax.jit(lambda p, t: pp_apply_model(
                cfg, p, t, mesh=mesh, n_micro=2))(params, toks)
            pg = jax.jit(jax.grad(lambda p: pp_loss(
                cfg, p, batch, mesh=mesh, n_micro=2)))(params)
        assert float(jnp.abs(out - ref).max()) < 1e-4
        ge = max(float(jnp.abs(a - b).max()) for a, b in
                 zip(jax.tree.leaves(pg), jax.tree.leaves(ref_grads)))
        assert ge < 1e-4, ge
        print("ok", ge)
        """)
