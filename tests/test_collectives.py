"""LCX p2p-built collectives vs native XLA collectives (vmap ranks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx

N = 4


def run(fn, shape=(8,)):
    xs = jnp.arange(float(N * int(np.prod(shape)))).reshape((N,) + shape)

    def body(x):
        lcx.init()
        return fn(x, lcx.Device(axis="x"))

    return jax.vmap(body, axis_name="x")(xs), xs


@pytest.mark.parametrize("backend", ["ring", "native"])
def test_all_gather(backend):
    out, xs = run(lambda x, d: lcx.all_gather(x, device=d, backend=backend))
    for r in range(N):
        np.testing.assert_allclose(out[r], xs.reshape(-1))


@pytest.mark.parametrize("backend", ["ring", "native"])
def test_reduce_scatter(backend):
    out, xs = run(lambda x, d: lcx.reduce_scatter(x, device=d,
                                                  backend=backend))
    total = np.asarray(xs.sum(0)).reshape(N, -1)
    for r in range(N):
        np.testing.assert_allclose(out[r], total[r])


@pytest.mark.parametrize("backend", ["ring", "native"])
@pytest.mark.parametrize("shape", [(8,), (3, 5), (7,)])
def test_all_reduce(backend, shape):
    out, xs = run(lambda x, d: lcx.all_reduce(x, device=d,
                                              backend=backend), shape)
    for r in range(N):
        np.testing.assert_allclose(out[r], np.asarray(xs.sum(0)),
                                   rtol=1e-6)


@pytest.mark.parametrize("backend", ["pairwise", "native"])
def test_all_to_all(backend):
    out, xs = run(lambda x, d: lcx.all_to_all(x, device=d,
                                              backend=backend))
    x_np = np.asarray(xs).reshape(N, N, 2)
    expect = np.swapaxes(x_np, 0, 1)
    np.testing.assert_allclose(np.asarray(out).reshape(N, N, 2), expect)


def test_broadcast():
    out, xs = run(lambda x, d: lcx.broadcast(x, device=d, root=2))
    for r in range(N):
        np.testing.assert_allclose(out[r], xs[2])


def test_ring_equals_native_allreduce_bf16():
    xs = jax.random.normal(jax.random.PRNGKey(0), (N, 16)
                           ).astype(jnp.bfloat16)

    def body(x):
        lcx.init()
        d = lcx.Device(axis="x")
        return (lcx.all_reduce(x, device=d, backend="ring"),
                lcx.all_reduce(x, device=d, backend="native"))

    ring, native = jax.vmap(body, axis_name="x")(xs)
    np.testing.assert_allclose(np.asarray(ring, np.float32),
                               np.asarray(native, np.float32),
                               rtol=2e-2, atol=1e-2)


def test_device_stats_count_transfers():
    def body(x):
        lcx.init()
        d = lcx.Device(axis="x")
        lcx.all_gather(x, device=d, backend="ring")
        return jnp.float32(d.stats["transfers"])

    out = jax.vmap(body, axis_name="x")(jnp.arange(4.0))
    assert float(out[0]) == N - 1      # ring hops
