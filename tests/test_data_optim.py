"""Data pipeline determinism + optimizer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLMDataset
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, global_norm)


# -- data ---------------------------------------------------------------------
def test_dataset_deterministic_across_instances():
    a = SyntheticLMDataset(vocab=100, seq_len=32, global_batch=8, seed=3)
    b = SyntheticLMDataset(vocab=100, seq_len=32, global_batch=8, seed=3)
    np.testing.assert_array_equal(a.batch(5)["tokens"],
                                  b.batch(5)["tokens"])


def test_dataset_row_slices_consistent():
    """Any worker regenerating rows [lo,hi) gets the same data as the
    full batch sliced — the resharding/restart invariant."""
    ds = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=8, seed=1)
    full = ds.batch(3)["tokens"]
    part = ds.batch(3, 2, 6)["tokens"]
    np.testing.assert_array_equal(full[2:6], part)


def test_dataset_steps_differ():
    ds = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=4, seed=1)
    assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])


def test_labels_are_shifted_tokens():
    ds = SyntheticLMDataset(vocab=50, seq_len=16, global_batch=2, seed=0)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# -- optimizer ------------------------------------------------------------------
def test_adamw_matches_reference_step():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3])}
    state = adamw_init(params)
    lr, b1, b2, eps, wd = 0.01, 0.9, 0.95, 1e-8, 0.1
    new, state2 = adamw_update(params, grads, state, lr=lr, b1=b1, b2=b2,
                               eps=eps, weight_decay=wd)
    # hand-rolled single step
    m = 0.1 * np.asarray(grads["w"])
    v = 0.05 * np.asarray(grads["w"]) ** 2
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    ref = np.asarray(params["w"]) - lr * (
        mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(params["w"]))
    np.testing.assert_allclose(np.asarray(new["w"]), ref, rtol=1e-5)
    assert int(state2.step) == 1


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params, dtype=jnp.bfloat16)
    assert state.m["w"].dtype == jnp.bfloat16
    new, _ = adamw_update(params, {"w": jnp.ones((8,), jnp.bfloat16)},
                          state, lr=jnp.float32(0.1))
    assert new["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    norm = float(global_norm(g))
    clipped, reported = clip_by_global_norm(g, 1.0)
    assert reported == pytest.approx(norm)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the limit: unchanged
    same, _ = clip_by_global_norm(g, norm * 2)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, min_ratio=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(110))) == pytest.approx(0.1, abs=1e-6)
    mid = float(lr(jnp.int32(60)))
    assert 0.1 < mid < 1.0
