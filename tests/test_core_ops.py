"""Communication-posting operations under multi-rank emulation
(vmap with a bound axis name binds lax.ppermute exactly like shard_map —
one CPU device suffices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx

N = 4


def ranked(fn, n=N, width=None):
    """Run fn(x) per-rank under an axis named 'x'."""
    xs = jnp.arange(float(n)) if width is None else \
        jnp.arange(float(n * width)).reshape(n, width)
    return jax.vmap(fn, axis_name="x")(xs)


def dev():
    return lcx.Device(axis="x")


def test_sendrecv_ring():
    def body(x):
        lcx.init()
        return lcx.sendrecv(x, lcx.Perm.shift(1), device=dev())
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_put_with_remote_signal():
    """put + remote completion = RDMA write with signal."""
    def body(x):
        lcx.init()
        sync = lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(2)).remote_comp(sync).device(dev())()
        lcx.progress()
        (ev,) = sync.wait()
        assert ev.remote and ev.op == "put"
        return ev.payload
    out = ranked(body)
    np.testing.assert_allclose(out, [2, 3, 0, 1])


def test_get_fetches_from_peer():
    def body(x):
        lcx.init()
        h = lcx.get_x(x).perm(lcx.Perm.shift(1)).device(dev())()
        lcx.progress()
        return h.payload()
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_am_function_handler():
    """Active message with a *function handler* remote completion."""
    def body(x):
        lcx.init()
        fh = lcx.FunctionHandler(lambda ev: ev.payload + 100)
        lcx.am_x(x).perm(lcx.Perm.shift(1)).remote_comp(fh).device(dev())()
        lcx.progress()
        return fh.results[0]
    out = ranked(body)
    np.testing.assert_allclose(out, [103, 100, 101, 102])


def test_am_completion_queue():
    """paper: 'LCI's active message operation supports remote completion
    objects of any type, such as completion queues'."""
    def body(x):
        lcx.init()
        cq = lcx.CompletionQueue()
        lcx.am_x(x).perm(lcx.Perm.shift(1)).remote_comp(cq).device(dev())()
        lcx.am_x(x * 10).perm(lcx.Perm.shift(1)).remote_comp(cq) \
            .device(dev())()
        lcx.progress()
        evs = cq.pop_all()
        return evs[0].payload + evs[1].payload
    out = ranked(body)
    np.testing.assert_allclose(out, [33, 0, 11, 22])


def test_op_and_completion_orthogonal():
    """Any op can pair with any completion type (send w/ CQ, put w/
    synchronizer, am w/ counter)."""
    def body(x):
        lcx.init()
        cq = lcx.CompletionQueue()
        cnt = lcx.CounterCompletion(target=1)
        sync = lcx.Synchronizer()
        lcx.send_x(x).perm(lcx.Perm.shift(1)).comp(cq).device(dev())()
        lcx.recv_x(x).perm(lcx.Perm.shift(1)).comp(sync).device(dev())()
        lcx.am_x(x).perm(lcx.Perm.shift(2)).remote_comp(cnt).device(dev())()
        lcx.progress()
        assert len(cq) == 1 and cnt.ready()
        (ev,) = sync.wait()
        return ev.payload
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_same_device_different_completions():
    """Two ops share a device but use different completion objects."""
    def body(x):
        lcx.init()
        d = dev()
        s1, s2 = lcx.Synchronizer(), lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(s1).device(d)()
        lcx.put_x(-x).perm(lcx.Perm.shift(1)).remote_comp(s2).device(d)()
        lcx.progress()
        return s1.wait()[0].payload - s2.wait()[0].payload
    out = ranked(body)
    np.testing.assert_allclose(out, [6, 0, 2, 4])


def test_cross_device_matching_via_shared_engine():
    """sends/recvs on *different devices* still match when they share a
    matching engine (paper §2.2)."""
    def body(x):
        lcx.init()
        eng = lcx.MatchingEngine(kind="map", policy="tag_only")
        d1, d2 = lcx.Device(axis="x"), lcx.Device(axis="x")
        sync = lcx.Synchronizer(threshold=2)
        lcx.send_x(x).perm(lcx.Perm.shift(1)).tag(9).comp(sync) \
            .device(d1).matching_engine(eng)()
        lcx.recv_x(x).perm(lcx.Perm.shift(1)).tag(9).comp(sync) \
            .device(d2).matching_engine(eng)()
        lcx.progress()
        evs = sync.wait()
        (payload,) = [e.payload for e in evs if e.payload is not None]
        return payload
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_aggregation_packs_eager_messages():
    """Fine-grained sends sharing (axis, perm, dtype) ride one packed
    transfer (doorbell batching analogue); rendezvous-size messages go
    alone."""
    def body(x):
        lcx.init()
        d = dev()
        pool = lcx.PacketPool(packet_size=64)   # bytes
        syncs = [lcx.Synchronizer() for _ in range(3)]
        for i, s in enumerate(syncs):
            lcx.put_x(x + i).perm(lcx.Perm.shift(1)).remote_comp(s) \
                .device(d)()
        big = lcx.Synchronizer()
        lcx.put_x(jnp.broadcast_to(x, (64,))).perm(lcx.Perm.shift(1)) \
            .remote_comp(big).device(d)()
        lcx.progress_x().pool(pool)()
        assert pool.stats["aggregated_transfers"] == 1
        assert pool.stats["eager_msgs"] == 3
        assert pool.stats["rendezvous_msgs"] == 1
        vals = [s.wait()[0].payload for s in syncs]
        return vals[0] + vals[1] * 10 + vals[2] * 100 + big.wait()[0].payload[0]
    out = ranked(body)
    # neighbour value v: v + (v+1)*10 + (v+2)*100 + v
    v = np.array([3.0, 0.0, 1.0, 2.0])
    np.testing.assert_allclose(out, v + (v + 1) * 10 + (v + 2) * 100 + v)


def test_progress_max_transfers_leaves_rest_pending():
    def body(x):
        lcx.init()
        d = dev()
        s1, s2 = lcx.Synchronizer(), lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(s1).device(d) \
            .allow_aggregation(False)()
        lcx.put_x(x).perm(lcx.Perm.shift(2)).remote_comp(s2).device(d) \
            .allow_aggregation(False)()
        n1 = lcx.progress_x().max_transfers(1)()
        pending_after_first = lcx.runtime().pending_count()
        n2 = lcx.progress_x()()
        assert s1.ready() and s2.ready()
        return jnp.float32(pending_after_first)
    out = ranked(body)
    np.testing.assert_allclose(out, [1, 1, 1, 1])


def test_explicit_progress_required():
    def body(x):
        lcx.init()
        sync = lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(sync).device(dev())()
        ready_before = sync.ready()
        lcx.progress()
        assert not ready_before and sync.ready()
        return sync.wait()[0].payload
    ranked(body)


def test_shape_mismatch_raises():
    def body(x):
        lcx.init()
        d = dev()
        sync = lcx.Synchronizer(threshold=2)
        lcx.send_x(jnp.zeros(3)).perm(lcx.Perm.shift(1)).comp(sync) \
            .device(d)()
        lcx.recv_x(jnp.zeros(5)).perm(lcx.Perm.shift(1)).comp(sync) \
            .device(d)()
        with pytest.raises(ValueError):
            lcx.progress()
        return x
    ranked(body)
