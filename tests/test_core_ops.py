"""Communication-posting operations under multi-rank emulation
(vmap with a bound axis name binds lax.ppermute exactly like shard_map —
one CPU device suffices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx

N = 4


def ranked(fn, n=N, width=None):
    """Run fn(x) per-rank under an axis named 'x'."""
    xs = jnp.arange(float(n)) if width is None else \
        jnp.arange(float(n * width)).reshape(n, width)
    return jax.vmap(fn, axis_name="x")(xs)


def dev():
    return lcx.Device(axis="x")


def test_sendrecv_ring():
    def body(x):
        lcx.init()
        return lcx.sendrecv(x, lcx.Perm.shift(1), device=dev())
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_put_with_remote_signal():
    """put + remote completion = RDMA write with signal."""
    def body(x):
        lcx.init()
        sync = lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(2)).remote_comp(sync).device(dev())()
        lcx.progress()
        (ev,) = sync.wait()
        assert ev.remote and ev.op == "put"
        return ev.payload
    out = ranked(body)
    np.testing.assert_allclose(out, [2, 3, 0, 1])


def test_get_fetches_from_peer():
    def body(x):
        lcx.init()
        h = lcx.get_x(x).perm(lcx.Perm.shift(1)).device(dev())()
        lcx.progress()
        return h.payload()
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_am_function_handler():
    """Active message with a *function handler* remote completion."""
    def body(x):
        lcx.init()
        fh = lcx.FunctionHandler(lambda ev: ev.payload + 100)
        lcx.am_x(x).perm(lcx.Perm.shift(1)).remote_comp(fh).device(dev())()
        lcx.progress()
        return fh.results[0]
    out = ranked(body)
    np.testing.assert_allclose(out, [103, 100, 101, 102])


def test_am_completion_queue():
    """paper: 'LCI's active message operation supports remote completion
    objects of any type, such as completion queues'."""
    def body(x):
        lcx.init()
        cq = lcx.CompletionQueue()
        lcx.am_x(x).perm(lcx.Perm.shift(1)).remote_comp(cq).device(dev())()
        lcx.am_x(x * 10).perm(lcx.Perm.shift(1)).remote_comp(cq) \
            .device(dev())()
        lcx.progress()
        evs = cq.pop_all()
        return evs[0].payload + evs[1].payload
    out = ranked(body)
    np.testing.assert_allclose(out, [33, 0, 11, 22])


def test_op_and_completion_orthogonal():
    """Any op can pair with any completion type (send w/ CQ, put w/
    synchronizer, am w/ counter)."""
    def body(x):
        lcx.init()
        cq = lcx.CompletionQueue()
        cnt = lcx.CounterCompletion(target=1)
        sync = lcx.Synchronizer()
        lcx.send_x(x).perm(lcx.Perm.shift(1)).comp(cq).device(dev())()
        lcx.recv_x(x).perm(lcx.Perm.shift(1)).comp(sync).device(dev())()
        lcx.am_x(x).perm(lcx.Perm.shift(2)).remote_comp(cnt).device(dev())()
        lcx.progress()
        assert len(cq) == 1 and cnt.ready()
        (ev,) = sync.wait()
        return ev.payload
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_same_device_different_completions():
    """Two ops share a device but use different completion objects."""
    def body(x):
        lcx.init()
        d = dev()
        s1, s2 = lcx.Synchronizer(), lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(s1).device(d)()
        lcx.put_x(-x).perm(lcx.Perm.shift(1)).remote_comp(s2).device(d)()
        lcx.progress()
        return s1.wait()[0].payload - s2.wait()[0].payload
    out = ranked(body)
    np.testing.assert_allclose(out, [6, 0, 2, 4])


def test_cross_device_matching_via_shared_engine():
    """sends/recvs on *different devices* still match when they share a
    matching engine (paper §2.2)."""
    def body(x):
        lcx.init()
        eng = lcx.MatchingEngine(kind="map", policy="tag_only")
        d1, d2 = lcx.Device(axis="x"), lcx.Device(axis="x")
        sync = lcx.Synchronizer(threshold=2)
        lcx.send_x(x).perm(lcx.Perm.shift(1)).tag(9).comp(sync) \
            .device(d1).matching_engine(eng)()
        lcx.recv_x(x).perm(lcx.Perm.shift(1)).tag(9).comp(sync) \
            .device(d2).matching_engine(eng)()
        lcx.progress()
        evs = sync.wait()
        (payload,) = [e.payload for e in evs if e.payload is not None]
        return payload
    out = ranked(body)
    np.testing.assert_allclose(out, [3, 0, 1, 2])


def test_aggregation_packs_eager_messages():
    """Fine-grained sends sharing (axis, perm, dtype) ride one packed
    transfer (doorbell batching analogue); rendezvous-size messages go
    alone."""
    def body(x):
        lcx.init()
        d = dev()
        pool = lcx.PacketPool(packet_size=64)   # bytes
        syncs = [lcx.Synchronizer() for _ in range(3)]
        for i, s in enumerate(syncs):
            lcx.put_x(x + i).perm(lcx.Perm.shift(1)).remote_comp(s) \
                .device(d)()
        big = lcx.Synchronizer()
        lcx.put_x(jnp.broadcast_to(x, (64,))).perm(lcx.Perm.shift(1)) \
            .remote_comp(big).device(d)()
        lcx.progress_x().pool(pool)()
        assert pool.stats["aggregated_transfers"] == 1
        assert pool.stats["eager_msgs"] == 3
        assert pool.stats["rendezvous_msgs"] == 1
        vals = [s.wait()[0].payload for s in syncs]
        return vals[0] + vals[1] * 10 + vals[2] * 100 + big.wait()[0].payload[0]
    out = ranked(body)
    # neighbour value v: v + (v+1)*10 + (v+2)*100 + v
    v = np.array([3.0, 0.0, 1.0, 2.0])
    np.testing.assert_allclose(out, v + (v + 1) * 10 + (v + 2) * 100 + v)


def test_progress_max_transfers_leaves_rest_pending():
    def body(x):
        lcx.init()
        d = dev()
        s1, s2 = lcx.Synchronizer(), lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(s1).device(d) \
            .allow_aggregation(False)()
        lcx.put_x(x).perm(lcx.Perm.shift(2)).remote_comp(s2).device(d) \
            .allow_aggregation(False)()
        n1 = lcx.progress_x().max_transfers(1)()
        pending_after_first = lcx.runtime().pending_count()
        n2 = lcx.progress_x()()
        assert s1.ready() and s2.ready()
        return jnp.float32(pending_after_first)
    out = ranked(body)
    np.testing.assert_allclose(out, [1, 1, 1, 1])


def test_explicit_progress_required():
    def body(x):
        lcx.init()
        sync = lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(sync).device(dev())()
        ready_before = sync.ready()
        lcx.progress()
        assert not ready_before and sync.ready()
        return sync.wait()[0].payload
    ranked(body)


def test_shape_mismatch_raises():
    def body(x):
        lcx.init()
        d = dev()
        sync = lcx.Synchronizer(threshold=2)
        lcx.send_x(jnp.zeros(3)).perm(lcx.Perm.shift(1)).comp(sync) \
            .device(d)()
        lcx.recv_x(jnp.zeros(5)).perm(lcx.Perm.shift(1)).comp(sync) \
            .device(d)()
        with pytest.raises(ValueError):
            lcx.progress()
        return x
    ranked(body)


def test_shape_mismatch_raises_on_aggregated_path():
    """The aggregated path must enforce the same send/recv shape check
    as the single-transfer path — aggregation can't silently reshape."""
    def body(x):
        lcx.init()
        d = dev()
        p = lcx.Perm.shift(1)
        s1 = lcx.Synchronizer(threshold=2)
        s2 = lcx.Synchronizer(threshold=2)
        # two eager same-perm pairs -> one aggregated group; the second
        # pair's recv shape is wrong
        lcx.send_x(jnp.zeros(3)).perm(p).tag(0).comp(s1).device(d)()
        lcx.recv_x(jnp.zeros(3)).perm(p).tag(0).comp(s1).device(d)()
        lcx.send_x(jnp.zeros(4)).perm(p).tag(1).comp(s2).device(d)()
        lcx.recv_x(jnp.zeros(6)).perm(p).tag(1).comp(s2).device(d)()
        with pytest.raises(ValueError):
            lcx.progress()
        return x
    ranked(body)


# -- progress fast path: plan cache, byte packing, transfer accounting -------
def test_mixed_dtype_eager_messages_share_one_transfer():
    """Byte-view packing: eager messages with different (bitcast-safe)
    dtypes on one perm ride a single aggregated transfer."""
    def body(x):
        lcx.init()
        d = dev()
        pool = lcx.PacketPool()
        sf = lcx.Synchronizer()
        si = lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(sf).device(d)()
        lcx.put_x(jnp.int32(5)).perm(lcx.Perm.shift(1)).remote_comp(si) \
            .device(d)()
        n = lcx.progress_x().pool(pool)()
        assert n == 1
        assert pool.stats["aggregated_transfers"] == 1
        assert pool.stats["eager_msgs"] == 2
        vi = si.wait()[0].payload
        assert vi.dtype == jnp.int32
        return sf.wait()[0].payload + vi.astype(jnp.float32)
    out = ranked(body)
    np.testing.assert_allclose(out, np.array([3.0, 0.0, 1.0, 2.0]) + 5.0)


def test_aggregation_plan_cached_across_progress_calls():
    """Steady-state loops reuse the concat/slice plan instead of
    re-deriving it on every progress call."""
    def body(x):
        lcx.init()
        d = dev()
        pool = lcx.PacketPool()
        outs = []
        for step in range(3):
            s1, s2 = lcx.Synchronizer(), lcx.Synchronizer()
            lcx.put_x(x + step).perm(lcx.Perm.shift(1)) \
                .remote_comp(s1).device(d)()
            lcx.put_x(x * step).perm(lcx.Perm.shift(1)) \
                .remote_comp(s2).device(d)()
            lcx.progress_x().pool(pool)()
            outs.append(s1.wait()[0].payload + s2.wait()[0].payload)
        stats = lcx.runtime().plan_stats
        assert stats["misses"] == 1 and stats["hits"] == 2
        return sum(outs)
    out = ranked(body)
    # neighbour v: sum over steps of (v+step) + v*step = 3v+3 + 3v
    v = np.array([3.0, 0.0, 1.0, 2.0])
    np.testing.assert_allclose(out, 6 * v + 3)


def test_max_transfers_counts_actual_transfers_not_groups():
    """Loopback deliveries are zero transfers and never consume the
    max_transfers budget; an aggregated group costs exactly one."""
    def body(x):
        lcx.init()
        loop_dev = lcx.Device()           # loopback: no transfer
        axis_dev = dev()
        s_loop = lcx.Synchronizer()
        s_axis = lcx.Synchronizer()
        lcx.put_x(x).remote_comp(s_loop).device(loop_dev)()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(s_axis) \
            .device(axis_dev)()
        # budget 1: the loopback match is free, the axis put fits
        n = lcx.progress_x().max_transfers(1)()
        assert n == 1
        assert s_loop.ready() and s_axis.ready()
        assert lcx.runtime().pending_count() == 0
        return s_axis.wait()[0].payload + s_loop.wait()[0].payload
    out = ranked(body)
    v = np.array([3.0, 0.0, 1.0, 2.0])
    np.testing.assert_allclose(out, v + np.arange(4.0))


def test_pool_msg_stats_not_double_counted_across_deferred_progress():
    """Matches re-enqueued by the max_transfers budget must not bump
    eager/rendezvous counters again when they finally execute."""
    def body(x):
        lcx.init()
        d = dev()
        pool = lcx.PacketPool()
        s1, s2 = lcx.Synchronizer(), lcx.Synchronizer()
        lcx.put_x(x).perm(lcx.Perm.shift(1)).remote_comp(s1).device(d) \
            .allow_aggregation(False)()
        lcx.put_x(x).perm(lcx.Perm.shift(2)).remote_comp(s2).device(d) \
            .allow_aggregation(False)()
        n1 = lcx.progress_x().pool(pool).max_transfers(1)()
        assert n1 == 1
        assert pool.stats["rendezvous_msgs"] == 1   # deferred one uncounted
        n2 = lcx.progress_x().pool(pool)()
        assert n2 == 1
        assert pool.stats["rendezvous_msgs"] == 2   # not 3
        assert pool.stats["raw_transfers"] == 2
        return s1.wait()[0].payload + s2.wait()[0].payload
    ranked(body)
