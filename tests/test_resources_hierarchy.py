"""Resource hierarchy (Runtime → NetContext → Device → Endpoint) tests.

The paper's feature (b): fine-grained resource mapping for library
interoperation, per-thread isolation, and flexibility.  Two runtimes —
or two isolated devices on one runtime — must coexist in one process
with zero cross-talk in matching, ``pending()`` accounting, fault
injection, and ``finalize()`` leak checks.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as lcx


@pytest.fixture(autouse=True)
def fresh_runtime():
    lcx.init()
    yield
    lcx.finalize(strict=False)


def _roundtrip(tag, runtime=None, device=None, endpoint=None):
    """Post a tagged loopback send/recv pair on explicit resources and
    progress it; returns the received payload."""
    sync = lcx.Synchronizer(threshold=1)
    lcx.send_x(jnp.full((4,), float(tag))).tag(tag).runtime(runtime) \
        .device(device).endpoint(endpoint)()
    lcx.recv_x(jnp.zeros(4)).tag(tag).comp(sync).runtime(runtime) \
        .device(device).endpoint(endpoint)()
    lcx.progress_x().runtime(runtime).device(device).endpoint(endpoint)()
    (ev,) = sync.wait()
    return ev.payload


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------
def test_hierarchy_construction():
    rt = lcx.Runtime(name="mine")
    assert rt.default_net_context in rt.net_contexts
    nc = rt.default_net_context
    dev = rt.default_device
    assert dev in nc.devices and dev is nc.default_device
    assert dev.net_context is nc and dev.runtime is rt
    ep = rt.default_endpoint
    assert ep is dev.default_endpoint and ep in dev.endpoints
    assert ep.runtime is rt
    # default resources ARE the default device's private resources
    assert rt.default_engine is dev.engine
    assert rt.default_pool is dev.pool
    assert rt.default_cq is dev.cq


def test_every_level_independently_constructible():
    rt = lcx.Runtime(alloc_default_resources=False)
    assert rt.default_device is None
    nc = lcx.NetContext(runtime=rt, backend="sim")
    dev = nc.device(name="worker-0")
    ep = dev.endpoint()
    assert dev.get_attr_backend() == "sim"
    assert rt.devices() == [dev]
    assert ep.engine is dev.engine
    # endpoint with private resources never shares the device's
    ep2 = dev.endpoint(matching_engine=lcx.MatchingEngine(),
                       cq=lcx.CompletionQueue())
    assert ep2.engine is not dev.engine and ep2.cq is not dev.cq


def test_netcontext_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        lcx.NetContext(backend="infiniband")


def test_floating_device_resolves_runtime_defaults():
    # bare Device() = legacy behaviour: shares the global default engine,
    # so two floating devices on one axis still match each other
    d1, d2 = lcx.Device(), lcx.Device()
    res1 = lcx.resolve_resources(device=d1)
    res2 = lcx.resolve_resources(device=d2)
    assert res1.engine is res2.engine is lcx.runtime().default_engine
    assert res1.runtime is lcx.runtime()


def test_resolution_order_endpoint_over_device_over_runtime():
    rt = lcx.Runtime()
    dev = rt.device()
    ep_cq = lcx.CompletionQueue()
    ep = dev.endpoint(cq=ep_cq)
    res = lcx.resolve_resources(endpoint=ep)
    assert res.runtime is rt
    assert res.device is dev
    assert res.cq is ep_cq              # endpoint wins
    assert res.engine is dev.engine     # unset on endpoint -> device's
    res_dev = lcx.resolve_resources(device=dev)
    assert res_dev.cq is dev.cq         # no endpoint -> device's cq


def test_resolution_rejects_mismatched_endpoint_device():
    rt = lcx.Runtime()
    d1, d2 = rt.device(), rt.device()
    with pytest.raises(ValueError, match="belongs to"):
        lcx.resolve_resources(endpoint=d1.default_endpoint, device=d2)


# ---------------------------------------------------------------------------
# Two runtimes: zero cross-talk
# ---------------------------------------------------------------------------
def test_two_runtimes_no_crosstalk_matching_or_pending():
    rt_a = lcx.Runtime(name="libA")
    rt_b = lcx.Runtime(name="libB")
    # same tag on both runtimes: posts must match within their own
    # runtime's engine, never across
    sa, sb = lcx.Synchronizer(threshold=1), lcx.Synchronizer(threshold=1)
    lcx.send_x(jnp.full((2,), 1.0)).tag(9).runtime(rt_a)()
    lcx.send_x(jnp.full((2,), 2.0)).tag(9).runtime(rt_b)()
    lcx.recv_x(jnp.zeros(2)).tag(9).comp(sa).runtime(rt_a)()
    lcx.recv_x(jnp.zeros(2)).tag(9).comp(sb).runtime(rt_b)()
    assert rt_a.pending_count() == 1
    assert rt_b.pending_count() == 1
    assert lcx.runtime().pending_count() == 0
    # progress one runtime: only its transfer lands
    lcx.progress_x().runtime(rt_a)()
    assert sa.ready() and not sb.ready()
    assert rt_a.pending_count() == 0 and rt_b.pending_count() == 1
    lcx.progress_x().runtime(rt_b)()
    (ev_a,), (ev_b,) = sa.wait(), sb.wait()
    np.testing.assert_allclose(ev_a.payload, 1.0)
    np.testing.assert_allclose(ev_b.payload, 2.0)


def test_two_runtimes_concurrent_interleaved_exchange():
    rt_a, rt_b = lcx.Runtime(), lcx.Runtime()
    # interleave posts across runtimes before any progress
    for tag in range(4):
        lcx.send_x(jnp.full((3,), float(tag))).tag(tag).runtime(rt_a)()
        lcx.send_x(jnp.full((3,), float(tag + 100))).tag(tag).runtime(rt_b)()
    cqa, cqb = lcx.CompletionQueue(), lcx.CompletionQueue()
    for tag in range(4):
        lcx.recv_x(jnp.zeros(3)).tag(tag).comp(cqa).runtime(rt_a)()
        lcx.recv_x(jnp.zeros(3)).tag(tag).comp(cqb).runtime(rt_b)()
    lcx.progress_x().runtime(rt_a)()
    lcx.progress_x().runtime(rt_b)()
    got_a = sorted(float(ev.payload[0]) for ev in cqa.pop_all())
    got_b = sorted(float(ev.payload[0]) for ev in cqb.pop_all())
    assert got_a == [0.0, 1.0, 2.0, 3.0]
    assert got_b == [100.0, 101.0, 102.0, 103.0]


def test_per_runtime_finalize_leak_check():
    rt_a, rt_b = lcx.Runtime(name="leaky"), lcx.Runtime(name="clean")
    lcx.send_x(jnp.zeros(2)).tag(1).runtime(rt_a)()
    lcx.recv_x(jnp.zeros(2)).tag(1).runtime(rt_a)()
    # clean runtime finalizes fine even while the leaky one has traffic
    lcx.finalize(strict=True, runtime=rt_b)
    with pytest.raises(RuntimeError, match="leaky"):
        lcx.finalize(strict=True, runtime=rt_a)


def test_finalize_error_names_devices():
    rt = lcx.Runtime(name="rt-x")
    d1 = rt.device(name="busy")
    lcx.send_x(jnp.zeros(2)).tag(1).device(d1)()
    lcx.recv_x(jnp.zeros(2)).tag(1).device(d1)()
    with pytest.raises(RuntimeError) as ei:
        rt.finalize(strict=True)
    assert "busy" in str(ei.value)
    assert "rt-x" in str(ei.value)


# ---------------------------------------------------------------------------
# Two isolated devices on ONE runtime
# ---------------------------------------------------------------------------
def test_two_isolated_devices_one_runtime_no_matching_crosstalk():
    rt = lcx.Runtime()
    d1, d2 = rt.device(name="t0"), rt.device(name="t1")
    assert d1.engine is not d2.engine
    s1 = lcx.Synchronizer(threshold=1)
    s2 = lcx.Synchronizer(threshold=1)
    # identical tags; the d1 recv must take d1's send, not d2's
    lcx.send_x(jnp.full((2,), 1.0)).tag(5).device(d1)()
    lcx.send_x(jnp.full((2,), 2.0)).tag(5).device(d2)()
    lcx.recv_x(jnp.zeros(2)).tag(5).comp(s1).device(d1)()
    lcx.recv_x(jnp.zeros(2)).tag(5).comp(s2).device(d2)()
    assert rt.pending_for(d1) == 1 and rt.pending_for(d2) == 1
    lcx.progress_x().device(d1)()
    assert s1.ready() and not s2.ready()
    assert rt.pending_for(d1) == 0 and rt.pending_for(d2) == 1
    lcx.progress_x().device(d2)()
    np.testing.assert_allclose(s1.wait()[0].payload, 1.0)
    np.testing.assert_allclose(s2.wait()[0].payload, 2.0)


def test_pending_by_device_breakdown():
    rt = lcx.Runtime()
    d1, d2 = rt.device(name="a"), rt.device(name="b")
    for _ in range(3):
        lcx.send_x(jnp.zeros(1)).device(d1)()
        lcx.recv_x(jnp.zeros(1)).device(d1)()
    lcx.send_x(jnp.zeros(1)).device(d2)()
    lcx.recv_x(jnp.zeros(1)).device(d2)()
    by_dev = rt.pending_by_device()
    assert by_dev[d1] == 3 and by_dev[d2] == 1
    assert d1.pending() == 3 and d2.pending() == 1
    assert rt.default_net_context.pending() == 4


def test_fault_injection_isolated_per_device():
    rt = lcx.Runtime()
    d_chaos = rt.device(name="chaos")
    d_clean = rt.device(name="clean")
    # 100% drop on the chaos device only
    d_chaos.install_transport(
        lcx.FaultyTransport(lcx.FaultPolicy(seed=1, drop=1.0)))
    s_chaos = lcx.Synchronizer(threshold=1)
    s_clean = lcx.Synchronizer(threshold=1)
    lcx.send_x(jnp.ones(2)).tag(1).device(d_chaos)()
    lcx.recv_x(jnp.zeros(2)).tag(1).comp(s_chaos).device(d_chaos)()
    lcx.send_x(jnp.ones(2)).tag(1).device(d_clean)()
    lcx.recv_x(jnp.zeros(2)).tag(1).comp(s_clean).device(d_clean)()
    lcx.progress_x().device(d_chaos)()
    lcx.progress_x().device(d_clean)()
    # chaos transfer dropped fatally (no retry budget); clean one landed
    (ev,) = s_chaos.wait(raise_on_error=False)
    assert ev.status is lcx.ErrorCode.FATAL
    (ev,) = s_clean.wait()
    assert ev.status.ok
    assert d_chaos.transport.stats["drops"] == 1


def test_fault_injection_isolated_per_runtime():
    rt_chaos, rt_clean = lcx.Runtime(), lcx.Runtime()
    lcx.install_transport(
        lcx.FaultyTransport(lcx.FaultPolicy(seed=2, drop=1.0)),
        runtime=rt_chaos)
    assert _roundtrip(3, runtime=rt_clean)[0] == 3.0   # unaffected
    s = lcx.Synchronizer(threshold=1)
    lcx.send_x(jnp.ones(2)).tag(4).runtime(rt_chaos)()
    lcx.recv_x(jnp.zeros(2)).tag(4).comp(s).runtime(rt_chaos)()
    lcx.progress_x().runtime(rt_chaos)()
    (ev,) = s.wait(raise_on_error=False)
    assert ev.status is lcx.ErrorCode.FATAL


def test_dead_device_drains_own_runtime_only():
    from repro.runtime.fault import fail_device
    rt = lcx.Runtime()
    dev = rt.device()
    lcx.send_x(jnp.zeros(2)).device(dev)()
    lcx.recv_x(jnp.zeros(2)).device(dev)()
    # global runtime untouched by this device's death
    _roundtrip(1)                       # traffic on the global default
    assert fail_device(dev) == 1        # drains rt's ledger via dev.runtime
    assert rt.pending_count() == 0
    assert lcx.runtime().pending_count() == 0


# ---------------------------------------------------------------------------
# install_transport delegation (global -> per-device)
# ---------------------------------------------------------------------------
def test_global_install_transport_delegates_to_devices():
    rt = lcx.runtime()
    dev = rt.device(name="extra")
    t = lcx.FaultyTransport(lcx.FaultPolicy(seed=0, drop=0.0))
    prev = lcx.install_transport(t)
    assert prev is None
    assert rt.transport is t
    assert rt.default_device.transport is t
    assert dev.transport is t
    # removal clears every device too
    assert lcx.install_transport(None) is t
    assert rt.default_device.transport is None and dev.transport is None


# ---------------------------------------------------------------------------
# FlexOp reuse across endpoints; plain() defaults
# ---------------------------------------------------------------------------
def test_flexop_clone_reuse_across_two_endpoints():
    rt = lcx.Runtime()
    ep1 = rt.device(name="e1").endpoint()
    ep2 = rt.device(name="e2").endpoint()
    proto = lcx.send_x(jnp.full((2,), 7.0)).tag(11)
    # one prototype op cloned onto two endpoints: each clone posts into
    # its own endpoint's engine
    proto.clone().endpoint(ep1)()
    proto.clone().endpoint(ep2)()
    assert ep1.stats["posted"] == 1 and ep2.stats["posted"] == 1
    s1, s2 = lcx.Synchronizer(threshold=1), lcx.Synchronizer(threshold=1)
    lcx.recv_x(jnp.zeros(2)).tag(11).comp(s1).endpoint(ep1)()
    lcx.recv_x(jnp.zeros(2)).tag(11).comp(s2).endpoint(ep2)()
    lcx.progress_x().runtime(rt)()
    np.testing.assert_allclose(s1.wait()[0].payload, 7.0)
    np.testing.assert_allclose(s2.wait()[0].payload, 7.0)
    # the prototype itself is untouched (no endpoint bound)
    assert proto.arg_or("endpoint", None) is None


def test_plain_shorthand_resolves_defaults():
    # plain() ops with no resource args use the global default runtime
    h_send = lcx.send(jnp.full((3,), 5.0), tag=2)
    h_recv = lcx.recv(jnp.zeros(3), tag=2)
    assert lcx.runtime().pending_count() == 1
    lcx.progress()
    np.testing.assert_allclose(h_recv.payload(), 5.0)
    assert h_send.status == "done"
    # posted on the runtime's default device
    assert h_send.posted.device is lcx.runtime().default_device


def test_plain_shorthand_accepts_explicit_runtime():
    rt = lcx.Runtime()
    lcx.send(jnp.full((2,), 9.0), tag=3, runtime=rt)
    h = lcx.recv(jnp.zeros(2), tag=3, runtime=rt)
    assert rt.pending_count() == 1 and lcx.runtime().pending_count() == 0
    lcx.progress(runtime=rt)
    np.testing.assert_allclose(h.payload(), 9.0)


# ---------------------------------------------------------------------------
# AMT executors on isolated runtimes
# ---------------------------------------------------------------------------
def test_executors_on_separate_runtimes_are_isolated():
    from repro.amt import Executor
    rt_a, rt_b = lcx.Runtime(name="exA"), lcx.Runtime(name="exB")
    ex_a = Executor(runtime=rt_a, name="exA")
    ex_b = Executor(runtime=rt_b, name="exB")
    got = {}

    def talker(key):
        def t(ctx):
            ctx.put(jnp.full((2,), float(len(key))))
            return ctx.suspend(lambda ev: got.__setitem__(key, ev.payload))
        return t

    ex_a.spawn(talker("a"))
    ex_b.spawn(talker("b"))
    ex_a.run()
    assert "a" in got and "b" not in got   # ex_b untouched by ex_a.run()
    ex_b.run()
    assert "b" in got
    assert ex_a.runtime is rt_a and ex_b.runtime is rt_b


# ---------------------------------------------------------------------------
# LCX_NO_GLOBAL_RUNTIME
# ---------------------------------------------------------------------------
def test_no_global_runtime_env_blocks_lazy_creation():
    code = textwrap.dedent("""
        import os
        os.environ["LCX_NO_GLOBAL_RUNTIME"] = "1"
        import repro.core as lcx
        try:
            lcx.runtime()
        except RuntimeError as e:
            assert "LCX_NO_GLOBAL_RUNTIME" in str(e)
        else:
            raise SystemExit("lazy runtime() should have raised")
        # explicit construction still works
        rt = lcx.Runtime()
        import jax.numpy as jnp
        lcx.send(jnp.ones(2), tag=1, runtime=rt)
        h = lcx.recv(jnp.zeros(2), tag=1, runtime=rt)
        lcx.progress(runtime=rt)
        assert float(h.payload().sum()) == 2.0
        # explicit init() installs the global despite the flag
        lcx.init()
        lcx.runtime()
        print("ok")
    """)
    env = dict(os.environ)
    env.pop("LCX_NO_GLOBAL_RUNTIME", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         )
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nERR:{out.stderr}"
    assert "ok" in out.stdout
