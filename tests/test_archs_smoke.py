"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_IDS, SHAPES, cells, get_config,
                                get_smoke_config)
from repro.models import (apply_model, decode_step, init_cache, init_model,
                          loss_fn, prefill)


def _batch(cfg, B=2, S=32):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "audio":
        batch["frontend"] = jax.random.normal(jax.random.PRNGKey(2),
                                              (B, S, cfg.d_model))
    elif cfg.frontend_len:
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    params, dims = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss), arch
    assert np.isfinite(float(metrics["xent"]))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    logits, aux = apply_model(cfg, params, batch["tokens"],
                              frontend_embeds=batch.get("frontend"))
    S = batch["tokens"].shape[1]
    extra = cfg.frontend_len if (cfg.frontend_len
                                 and cfg.family == "vlm") else 0
    assert logits.shape == (2, S + extra, cfg.vocab)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_smoke_config(a).causal])
def test_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = init_cache(cfg, B, 32)
    lg, caches = prefill(cfg, params, toks, caches)
    assert jnp.isfinite(lg).all()
    nxt = jnp.argmax(lg[:, -1], -1)[:, None]
    lg2, caches = decode_step(cfg, params, nxt, caches, jnp.int32(S))
    assert lg2.shape == (B, 1, cfg.vocab)
    assert jnp.isfinite(lg2).all()


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the published dimensions."""
    spec = {
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=24576, vocab=65536,
                                     n_experts=16, n_experts_per_tok=2),
        "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14,
                           n_kv_heads=2, d_ff=4864, vocab=151936),
        "command-r-plus-104b": dict(n_layers=64, d_model=12288, n_heads=96,
                                    n_kv_heads=8, d_ff=33792, vocab=256000),
        "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92544),
        "starcoder2-7b": dict(n_layers=32, d_model=4608, n_heads=36,
                              n_kv_heads=4, d_ff=18432, vocab=49152),
        "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                              n_kv_heads=16, d_ff=5120, vocab=504),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab=50280,
                            ssm_state=128),
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 vocab=129280, n_experts=256,
                                 n_experts_per_tok=8, moe_d_ff=2048),
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, vocab=151936,
                                  n_experts=128, n_experts_per_tok=8,
                                  moe_d_ff=768),
        "llava-next-mistral-7b": dict(n_layers=32, d_model=4096,
                                      n_heads=32, n_kv_heads=8,
                                      d_ff=14336, vocab=32000),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_cell_matrix():
    cs = cells()
    assert len(cs) == 31
    assert ("hubert-xlarge", "decode_32k") not in cs
    assert ("qwen2-0.5b", "long_500k") not in cs
    assert ("mamba2-130m", "long_500k") in cs
    assert ("jamba-1.5-large-398b", "long_500k") in cs
    assert all(s in SHAPES for _, s in cs)
