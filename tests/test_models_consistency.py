"""Model-family behaviour: train/prefill/decode agreement, oracle
agreement across MoE backends, flash vs full attention inside models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import (apply_model, decode_step, init_cache, init_model,
                          loss_fn, prefill)

F32 = dict(dtype=jnp.float32, param_dtype=jnp.float32, q_block=8)


def consistency(cfg, S=16, B=2, atol=5e-5):
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = init_cache(cfg, B, 2 * S)
    lg_pre, caches = prefill(cfg, params, toks, caches)
    nxt = jnp.argmax(lg_pre[:, -1], -1)[:, None]
    lg_dec, _ = decode_step(cfg, params, nxt, caches, jnp.int32(S))
    toks2 = jnp.concatenate([toks, nxt], 1)
    lg_full, _ = apply_model(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(lg_pre[:, -1]),
                               np.asarray(lg_full[:, S - 1]), atol=atol)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(lg_full[:, S]), atol=atol)


def test_dense_gqa_consistency():
    consistency(ModelConfig(name="d", n_layers=3, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab=97, **F32))


def test_qk_norm_and_bias_consistency():
    consistency(ModelConfig(name="d2", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=4, d_ff=128, vocab=97,
                            qkv_bias=True, qk_norm=True, head_dim=24,
                            **F32))


def test_sliding_window_consistency():
    consistency(ModelConfig(name="sw", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab=97,
                            sliding_window=8, norm="layer", act="gelu",
                            **F32))


def test_mla_consistency():
    consistency(ModelConfig(name="mla", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=4, d_ff=128, vocab=97,
                            q_lora_rank=32, kv_lora_rank=16,
                            qk_nope_head_dim=16, qk_rope_head_dim=8,
                            v_head_dim=16, **F32))


def test_ssm_consistency():
    consistency(ModelConfig(name="ssm", family="ssm", n_layers=3,
                            d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
                            vocab=97, ssm_state=16, ssm_head_dim=16,
                            ssm_chunk=8, tie_embeddings=True, **F32))


def test_hybrid_moe_consistency():
    consistency(ModelConfig(name="hyb", family="hybrid", n_layers=8,
                            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                            vocab=97, attn_layer_period=4,
                            attn_layer_offset=1, n_experts=4,
                            n_experts_per_tok=2, moe_d_ff=96,
                            expert_layer_period=2, expert_layer_offset=1,
                            moe_backend="sort", capacity_factor=8.0,
                            ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                            **F32))


def test_moe_dense_vs_sort_oracle():
    cfg_d = ModelConfig(name="o", family="moe", n_layers=1, d_model=32,
                        n_heads=2, n_kv_heads=2, d_ff=64, vocab=53,
                        n_experts=4, n_experts_per_tok=2, moe_d_ff=48,
                        moe_backend="dense", capacity_factor=16.0, **F32)
    cfg_s = dataclasses.replace(cfg_d, moe_backend="sort")
    p, _ = init_model(jax.random.PRNGKey(5), cfg_d)
    t = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0, 53)
    l1, _ = apply_model(cfg_d, p, t)
    l2, _ = apply_model(cfg_s, p, t)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-6)


def test_attn_impl_full_vs_chunked_vs_skip():
    cfg = ModelConfig(name="impl", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=97, **F32)
    p, _ = init_model(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97)
    outs = [apply_model(cfg, p, t, impl=i)[0]
            for i in ("full", "chunked", "chunked_causal_skip")]
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               atol=5e-5)


def test_vlm_frontend_prepended():
    cfg = ModelConfig(name="vlm", family="vlm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                      frontend="vision", frontend_len=4, **F32)
    p, _ = init_model(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 97)
    fe = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 64))
    logits, _ = apply_model(cfg, p, t, frontend_embeds=fe)
    assert logits.shape == (2, 12, 97)
    loss, m = loss_fn(cfg, p, {"tokens": t, "labels": t, "frontend": fe})
    assert jnp.isfinite(loss)


def test_encoder_bidirectional_attention():
    """Non-causal encoder: flipping the input changes outputs at all
    positions (information flows both ways)."""
    cfg = ModelConfig(name="enc", family="audio", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab=31,
                      causal=False, frontend="audio", **F32)
    p, _ = init_model(jax.random.PRNGKey(0), cfg)
    fe = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 32))
    t = jnp.zeros((1, 8), jnp.int32)
    l1, _ = apply_model(cfg, p, t, frontend_embeds=fe)
    l2, _ = apply_model(cfg, p, t, frontend_embeds=fe[:, ::-1])
    assert float(jnp.abs(l1[0, 0] - l2[0, 0]).max()) > 1e-6


def test_mtp_loss_present():
    cfg = ModelConfig(name="mtp", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=53, mtp_depth=1, **F32)
    p, _ = init_model(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 53)
    loss, m = loss_fn(cfg, p, {"tokens": t, "labels": jnp.roll(t, -1, 1)})
    assert "mtp" in m and jnp.isfinite(m["mtp"])
    assert float(loss) > float(m["xent"]) - 1e-6   # mtp adds to the loss
